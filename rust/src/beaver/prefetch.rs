//! Background triple prefetch: the offline phase of the offline/online
//! split (DESIGN.md §3).
//!
//! A [`PrefetchDealer`] owns a producer thread that expands the
//! deterministic dealer stream **in schedule order** ahead of the online
//! protocol: each [`DrawOp`] of the provisioning [`TripleSchedule`] is
//! expanded into a set of share buffers, double-buffered through a bounded
//! channel so the producer runs one AND round ahead of the consumer. The
//! engine's draw calls ([`TripleSource`]) then just swap in the pre-filled
//! buffers — **no PRG expansion happens on the online critical path**
//! (pinned by [`PrefetchStats::fallback_ops`]` == 0` in the tests).
//!
//! Correctness contract: the PRG stream is sequential, so prefetched
//! material is bit-identical to inline expansion **iff** the protocol's
//! draws arrive in exactly the scheduled order with exactly the scheduled
//! shapes. The consumer checks this op-by-op; a mismatch means the
//! schedule prediction is wrong and the streams have already diverged, so
//! the draw (and every draw after it — the source is *poisoned*) reports
//! the fatal [`Error::Beaver`] instead of silently desynchronizing the
//! parties. The error propagates through the engine and fails the
//! in-flight job; it does not panic the party thread (DESIGN.md §7).
//! Running off the *end* of a non-cycling schedule is not an error: the
//! dealer is recovered from the producer and the remaining draws are
//! served synchronously (transparent fallback, counted in
//! [`PrefetchStats::fallback_ops`]).
//!
//! Buffer discipline mirrors the engine's arena: the producer checks its
//! share buffers out of a private size-classed [`Arena`], consumed buffer
//! sets are recycled back over a return channel, and once one schedule
//! cycle plus the lookahead is warm the producer allocates nothing —
//! provisioning memory is O(lookahead), not O(rounds)
//! ([`PrefetchStats::producer_arena`]).
//!
//! Usage accounting stays consumer-ordered: each prefetched entry carries
//! the dealer's [`TripleUsage`] snapshot taken right after *its own*
//! expansion, and [`TripleSource::usage`] reports the snapshot of the last
//! entry the consumer actually took — so `usage()` observed between
//! protocol steps is bit-identical to the synchronous dealer's, even
//! while the producer runs ahead.
//!
//! # Verification (DESIGN.md §8)
//!
//! The producer/consumer hand-off is exercised three ways: the std tests
//! below check stream identity and cancellation end-to-end, the nightly
//! TSan CI job replays them under ThreadSanitizer, and the `loom_models`
//! module (compiled under `RUSTFLAGS="--cfg loom"`) model-checks the
//! bounded hand-off protocol itself — including the
//! cancel-while-parked-on-a-full-slot case that `Drop` relies on to join
//! the producer.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::thread::JoinHandle;

use super::schedule::{DrawOp, TripleSchedule};
use super::{TripleSource, TripleUsage, TtpDealer};
use crate::error::{Error, Result};
use crate::util::arena::{Arena, ArenaStats};

/// Completed draw ops the bounded hand-off channel holds: the consumer's
/// current op plus one ready op (classic double buffering; the producer
/// may additionally be expanding the next op, so at most `LOOKAHEAD + 2`
/// buffer sets circulate per size class).
const LOOKAHEAD: usize = 1;

/// One expanded draw: the op it satisfies, its filled share buffers (3 for
/// triples, 2 for daBits) and the producer-side accounting snapshots taken
/// right after expansion.
struct Prefetched {
    op: DrawOp,
    bufs: Vec<Vec<u64>>,
    usage: TripleUsage,
    producer_arena: ArenaStats,
}

/// Counters describing a [`PrefetchDealer`]'s traffic.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchStats {
    /// Draws served from pre-filled buffers (the offline phase did the
    /// expansion).
    pub prefetched_ops: u64,
    /// Draws served by inline expansion after the (non-cycling) schedule
    /// ran out. Zero on a correctly provisioned run — the acceptance
    /// criterion of the offline/online split.
    pub fallback_ops: u64,
    /// The producer thread's buffer-pool counters as of the last consumed
    /// entry (allocation misses must stay O(schedule), not O(rounds)).
    pub producer_arena: ArenaStats,
}

/// A [`TripleSource`] that precomputes the dealer stream on a background
/// thread (see the module docs). Construct with [`PrefetchDealer::spawn`]
/// and install with
/// [`GmwParty::enable_prefetch`](crate::gmw::GmwParty::enable_prefetch)
/// (or `set_triple_source`) **before any draw**: the prefetcher restarts
/// the dealer stream from the beginning.
///
/// Prefetching is a purely local decision — each party expands its *own*
/// stream, so a session may freely mix prefetching and synchronous
/// parties; outputs and wire bytes are identical either way.
pub struct PrefetchDealer {
    ready: Option<Receiver<Prefetched>>,
    recycle: Option<Sender<Vec<Vec<u64>>>>,
    warm: Option<Receiver<()>>,
    worker: Option<JoinHandle<TtpDealer>>,
    /// Engaged once the non-cycling schedule is exhausted: the recovered
    /// dealer, positioned exactly at the end of the expanded stream.
    fallback: Option<TtpDealer>,
    /// Set on the first schedule mismatch (or producer panic): the stream
    /// position is unrecoverable, so every later draw fails too.
    poisoned: bool,
    last_usage: TripleUsage,
    stats: PrefetchStats,
}

impl PrefetchDealer {
    /// Start the producer thread expanding `schedule` from `dealer`'s
    /// current stream position (normally a fresh dealer). With `cycle` the
    /// schedule repeats forever — the serving mode, where every admitted
    /// batch replays the same per-pass draw sequence; without it the
    /// producer stops after one pass and later draws fall back to inline
    /// expansion.
    pub fn spawn(dealer: TtpDealer, schedule: TripleSchedule, cycle: bool) -> PrefetchDealer {
        let (ready_tx, ready_rx) = sync_channel::<Prefetched>(LOOKAHEAD);
        let (recycle_tx, recycle_rx) = channel::<Vec<Vec<u64>>>();
        let (warm_tx, warm_rx) = channel::<()>();
        let worker = std::thread::Builder::new()
            .name("hb-prefetch".into())
            .spawn(move || producer(dealer, schedule, cycle, ready_tx, recycle_rx, warm_tx))
            // LINT-ALLOW: unwrap — OS thread-spawn failure at session setup
            // is unrecoverable; one producer thread per prefetcher.
            .expect("spawn prefetch producer");
        PrefetchDealer {
            ready: Some(ready_rx),
            recycle: Some(recycle_tx),
            warm: Some(warm_rx),
            worker: Some(worker),
            fallback: None,
            poisoned: false,
            last_usage: TripleUsage::default(),
            stats: PrefetchStats::default(),
        }
    }

    /// Block until the producer has expanded (at least) the first
    /// scheduled op, so the first online round pays zero expansion wait.
    /// The coordinator calls this before a party thread admits work.
    pub fn wait_warm(&mut self) {
        if let Some(w) = self.warm.take() {
            // Err means the producer already finished (empty or tiny
            // schedule) — equally warm.
            let _ = w.recv();
        }
    }

    /// Traffic counters (see [`PrefetchStats`]).
    pub fn stats(&self) -> PrefetchStats {
        self.stats
    }

    /// Take the next prefetched entry, checking that it matches the draw
    /// the protocol actually performs; engage the synchronous fallback
    /// once the producer is done. A mismatch (or a dead producer) is the
    /// fatal [`Error::Beaver`] — the expanded stream position cannot be
    /// rewound, so the source poisons itself and every later draw fails
    /// too (DESIGN.md §7).
    fn next(&mut self, want: DrawOp) -> Result<Option<Prefetched>> {
        if self.poisoned {
            return Err(Error::Beaver(
                "prefetch stream poisoned by an earlier schedule mismatch".into(),
            ));
        }
        if self.fallback.is_none() {
            let Some(ready) = self.ready.as_ref() else {
                return Err(Error::Beaver("prefetch hand-off channel closed".into()));
            };
            match ready.recv() {
                Ok(entry) => {
                    if entry.op != want {
                        self.poisoned = true;
                        return Err(Error::Beaver(format!(
                            "prefetch schedule mismatch: the protocol drew {want:?} but the \
                             provisioning schedule expected {:?}; the offline phase expanded \
                             the dealer stream in schedule order, so the streams have \
                             diverged — fix the TripleSchedule for this workload",
                            entry.op
                        )));
                    }
                    self.stats.prefetched_ops += 1;
                    self.stats.producer_arena = entry.producer_arena;
                    self.last_usage = entry.usage;
                    return Ok(Some(entry));
                }
                Err(_) => {
                    // Channel drained and producer exited: recover the
                    // dealer (positioned at the end of the expanded
                    // stream) for synchronous service.
                    let Some(worker) = self.worker.take() else {
                        return Err(Error::Beaver("prefetch producer already gone".into()));
                    };
                    match worker.join() {
                        Ok(dealer) => self.fallback = Some(dealer),
                        Err(_) => {
                            self.poisoned = true;
                            return Err(Error::Beaver(
                                "prefetch producer thread panicked".into(),
                            ));
                        }
                    }
                }
            }
        }
        self.stats.fallback_ops += 1;
        Ok(None)
    }

    /// The recovered synchronous dealer (invariant: engaged whenever
    /// [`PrefetchDealer::next`] returns `Ok(None)`).
    fn fallback_mut(&mut self) -> Result<&mut TtpDealer> {
        self.fallback
            .as_mut()
            .ok_or_else(|| Error::Beaver("prefetch fallback dealer missing".into()))
    }

    /// Return a consumed entry's buffers to the producer for reuse.
    fn finish(&mut self, entry: Prefetched) {
        if let Some(tx) = &self.recycle {
            // A failed send just means the producer already exited; the
            // buffers are dropped instead of reused.
            let _ = tx.send(entry.bufs);
        }
    }
}

impl TripleSource for PrefetchDealer {
    fn arith_triples_into(&mut self, a: &mut [u64], b: &mut [u64], c: &mut [u64]) -> Result<()> {
        match self.next(DrawOp::Arith { n: a.len() })? {
            Some(e) => {
                a.copy_from_slice(&e.bufs[0]);
                b.copy_from_slice(&e.bufs[1]);
                c.copy_from_slice(&e.bufs[2]);
                self.finish(e);
            }
            None => self.fallback_mut()?.arith_triples_into(a, b, c),
        }
        Ok(())
    }

    fn bin_triples_planes_into(
        &mut self,
        w: u32,
        n_seg: usize,
        segs: usize,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> Result<()> {
        match self.next(DrawOp::BinPlanes { w, n_seg, segs })? {
            Some(e) => {
                a.copy_from_slice(&e.bufs[0]);
                b.copy_from_slice(&e.bufs[1]);
                c.copy_from_slice(&e.bufs[2]);
                self.finish(e);
            }
            None => self.fallback_mut()?.bin_triples_planes_into(w, n_seg, segs, a, b, c),
        }
        Ok(())
    }

    fn dabits_into(&mut self, r_bin: &mut [u64], r_arith: &mut [u64]) -> Result<()> {
        match self.next(DrawOp::DaBits { n: r_bin.len() })? {
            Some(e) => {
                r_bin.copy_from_slice(&e.bufs[0]);
                r_arith.copy_from_slice(&e.bufs[1]);
                self.finish(e);
            }
            None => self.fallback_mut()?.dabits_into(r_bin, r_arith),
        }
        Ok(())
    }

    fn usage(&self) -> TripleUsage {
        match &self.fallback {
            Some(d) => d.usage(),
            None => self.last_usage,
        }
    }

    fn prefetch_stats(&self) -> Option<PrefetchStats> {
        Some(self.stats)
    }
}

impl Drop for PrefetchDealer {
    fn drop(&mut self) {
        // Closing the hand-off channel cancels the producer mid-stream:
        // its next (possibly blocked) send fails and it exits. Join so no
        // thread outlives the session.
        drop(self.ready.take());
        drop(self.recycle.take());
        drop(self.warm.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Producer thread: expand the schedule in order, hand completed ops over
/// the bounded channel, reuse recycled buffers. Returns the dealer so the
/// consumer can continue the stream synchronously after a non-cycling
/// schedule ends.
fn producer(
    mut dealer: TtpDealer,
    schedule: TripleSchedule,
    cycle: bool,
    ready: SyncSender<Prefetched>,
    recycle: Receiver<Vec<Vec<u64>>>,
    warm: Sender<()>,
) -> TtpDealer {
    let mut arena = Arena::new();
    if schedule.is_empty() {
        let _ = warm.send(());
        return dealer;
    }
    let mut warmed = false;
    loop {
        for op in &schedule.ops {
            // Fold returned buffer sets back into the pool first, so the
            // steady state re-expands into recycled memory.
            while let Ok(bufs) = recycle.try_recv() {
                for b in bufs {
                    arena.put_words(b);
                }
            }
            let entry = expand(&mut dealer, *op, &mut arena);
            if ready.send(entry).is_err() {
                return dealer; // consumer dropped: cancelled mid-stream
            }
            if !warmed {
                warmed = true;
                let _ = warm.send(());
            }
        }
        if !cycle {
            return dealer;
        }
    }
}

/// Expand one op into arena-pooled buffers and snapshot the accounting.
fn expand(dealer: &mut TtpDealer, op: DrawOp, arena: &mut Arena) -> Prefetched {
    let (nbufs, len) = op.buf_shape();
    // HOT-PATH-ALLOW: producer-side, off the online critical path — a 2-3
    // entry Vec per op; the big share buffers are arena-pooled.
    let mut bufs: Vec<Vec<u64>> = (0..nbufs).map(|_| arena.take_words(len)).collect();
    match op {
        DrawOp::Arith { .. } => {
            let (a, rest) = bufs.split_at_mut(1);
            let (b, c) = rest.split_at_mut(1);
            dealer.arith_triples_into(&mut a[0], &mut b[0], &mut c[0]);
        }
        DrawOp::BinPlanes { w, n_seg, segs } => {
            let (a, rest) = bufs.split_at_mut(1);
            let (b, c) = rest.split_at_mut(1);
            dealer.bin_triples_planes_into(w, n_seg, segs, &mut a[0], &mut b[0], &mut c[0]);
        }
        DrawOp::DaBits { .. } => {
            let (r_bin, r_arith) = bufs.split_at_mut(1);
            dealer.dabits_into(&mut r_bin[0], &mut r_arith[0]);
        }
    }
    Prefetched { op, bufs, usage: dealer.usage(), producer_arena: arena.stats() }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The prefetched stream is bit-identical to the synchronous dealer's
    /// — buffers and consumer-observed usage, op by op, for every party.
    #[test]
    fn prefetched_stream_matches_sync_dealer() {
        let parties = 3;
        let mut sched = TripleSchedule::new();
        sched.ops.push(DrawOp::Arith { n: 10 });
        sched.ops.push(DrawOp::BinPlanes { w: 6, n_seg: 100, segs: 2 });
        sched.ops.push(DrawOp::DaBits { n: 7 });
        sched.ops.push(DrawOp::BinPlanes { w: 1, n_seg: 65, segs: 1 });
        for party in 0..parties {
            let mut sync = TtpDealer::new(42, party, parties);
            let mut pf =
                PrefetchDealer::spawn(TtpDealer::new(42, party, parties), sched.clone(), false);
            pf.wait_warm();
            for op in &sched.ops {
                let (nbufs, len) = op.buf_shape();
                let mut s = vec![vec![0u64; len]; 3];
                let mut p = vec![vec![0u64; len]; 3];
                match *op {
                    DrawOp::Arith { .. } => {
                        let (s0, srest) = s.split_at_mut(1);
                        let (s1, s2) = srest.split_at_mut(1);
                        sync.arith_triples_into(&mut s0[0], &mut s1[0], &mut s2[0]);
                        let (p0, prest) = p.split_at_mut(1);
                        let (p1, p2) = prest.split_at_mut(1);
                        pf.arith_triples_into(&mut p0[0], &mut p1[0], &mut p2[0]).unwrap();
                    }
                    DrawOp::BinPlanes { w, n_seg, segs } => {
                        let (s0, srest) = s.split_at_mut(1);
                        let (s1, s2) = srest.split_at_mut(1);
                        sync.bin_triples_planes_into(
                            w, n_seg, segs, &mut s0[0], &mut s1[0], &mut s2[0],
                        );
                        let (p0, prest) = p.split_at_mut(1);
                        let (p1, p2) = prest.split_at_mut(1);
                        pf.bin_triples_planes_into(
                            w, n_seg, segs, &mut p0[0], &mut p1[0], &mut p2[0],
                        )
                        .unwrap();
                    }
                    DrawOp::DaBits { .. } => {
                        debug_assert_eq!(nbufs, 2);
                        let (s0, srest) = s.split_at_mut(1);
                        sync.dabits_into(&mut s0[0], &mut srest[0]);
                        let (p0, prest) = p.split_at_mut(1);
                        pf.dabits_into(&mut p0[0], &mut prest[0]).unwrap();
                    }
                }
                assert_eq!(s, p, "party={party} op={op:?}");
                assert_eq!(pf.usage(), sync.usage(), "party={party} op={op:?}");
            }
            let st = pf.stats();
            assert_eq!(st.prefetched_ops, sched.len() as u64);
            assert_eq!(st.fallback_ops, 0);
        }
    }

    /// Running past a non-cycling schedule falls back to transparent
    /// inline expansion — still stream-identical to the sync dealer.
    #[test]
    fn exhausted_schedule_falls_back_synchronously() {
        let mut sched = TripleSchedule::new();
        sched.ops.push(DrawOp::Arith { n: 4 });
        let mut sync = TtpDealer::new(7, 0, 2);
        let mut pf = PrefetchDealer::spawn(TtpDealer::new(7, 0, 2), sched, false);
        let draw_arith = |d: &mut dyn TripleSource, n: usize| {
            let mut a = vec![0u64; n];
            let mut b = vec![0u64; n];
            let mut c = vec![0u64; n];
            d.arith_triples_into(&mut a, &mut b, &mut c).unwrap();
            (a, b, c)
        };
        // Scheduled draw, then two unscheduled ones.
        assert_eq!(draw_arith(&mut pf, 4), draw_arith(&mut sync, 4));
        assert_eq!(draw_arith(&mut pf, 9), draw_arith(&mut sync, 9));
        let mut sb = (vec![0u64; 5], vec![0u64; 5]);
        let mut pb = (vec![0u64; 5], vec![0u64; 5]);
        sync.dabits_into(&mut sb.0, &mut sb.1);
        pf.dabits_into(&mut pb.0, &mut pb.1).unwrap();
        assert_eq!(sb, pb);
        assert_eq!(pf.usage(), sync.usage());
        let st = pf.stats();
        assert_eq!((st.prefetched_ops, st.fallback_ops), (1, 2));
    }

    /// A draw that diverges from the schedule is unrecoverable (the stream
    /// was expanded in schedule order): it reports the fatal
    /// `Error::Beaver` — propagated, not a panic — and poisons the source
    /// so every later draw fails too (DESIGN.md §7).
    #[test]
    fn schedule_mismatch_is_fatal_error() {
        let mut sched = TripleSchedule::new();
        sched.ops.push(DrawOp::Arith { n: 4 });
        let mut pf = PrefetchDealer::spawn(TtpDealer::new(7, 0, 2), sched, false);
        let mut r_bin = vec![0u64; 4];
        let mut r_arith = vec![0u64; 4];
        let err = pf.dabits_into(&mut r_bin, &mut r_arith).unwrap_err();
        assert!(matches!(err, Error::Beaver(_)), "got {err}");
        assert!(err.to_string().contains("schedule mismatch"), "got {err}");
        assert!(!err.is_retryable());
        // Poisoned: even the correctly-scheduled shape now fails.
        let mut a = vec![0u64; 4];
        let mut b = vec![0u64; 4];
        let mut c = vec![0u64; 4];
        let err2 = pf.arith_triples_into(&mut a, &mut b, &mut c).unwrap_err();
        assert!(matches!(err2, Error::Beaver(_)), "got {err2}");
    }

    /// Cycling producers refill the same schedule indefinitely and reuse
    /// recycled buffers (allocations bounded by the lookahead, not the
    /// number of cycles).
    #[test]
    fn cycling_producer_reuses_buffers() {
        let mut sched = TripleSchedule::new();
        sched.ops.push(DrawOp::Arith { n: 64 });
        sched.ops.push(DrawOp::DaBits { n: 64 });
        let mut sync = TtpDealer::new(3, 1, 2);
        let mut pf = PrefetchDealer::spawn(TtpDealer::new(3, 1, 2), sched.clone(), true);
        let cycles = 50;
        for _ in 0..cycles {
            let mut s = (vec![0u64; 64], vec![0u64; 64], vec![0u64; 64]);
            let mut p = (vec![0u64; 64], vec![0u64; 64], vec![0u64; 64]);
            sync.arith_triples_into(&mut s.0, &mut s.1, &mut s.2);
            pf.arith_triples_into(&mut p.0, &mut p.1, &mut p.2).unwrap();
            assert_eq!(s, p);
            sync.dabits_into(&mut s.0, &mut s.1);
            pf.dabits_into(&mut p.0, &mut p.1).unwrap();
            assert_eq!((&s.0, &s.1), (&p.0, &p.1));
        }
        let st = pf.stats();
        assert_eq!(st.prefetched_ops, 2 * cycles);
        assert_eq!(st.fallback_ops, 0);
        // 5 buffers per cycle, but only ~3 op-sets in flight at once:
        // allocation misses must not scale with the cycle count.
        let per_cycle: u64 = 3 + 2;
        assert!(
            st.producer_arena.alloc_misses <= (LOOKAHEAD as u64 + 2) * per_cycle,
            "producer allocated per cycle: {:?}",
            st.producer_arena
        );
        assert_eq!(pf.usage(), sync.usage());
    }

    /// Dropping the consumer cancels the producer cleanly at any point:
    /// before the first draw, mid-schedule, and while the producer is
    /// parked on a full hand-off channel.
    #[test]
    fn drop_cancels_producer_cleanly() {
        let mut sched = TripleSchedule::new();
        sched.ops.push(DrawOp::Arith { n: 1024 });
        sched.ops.push(DrawOp::DaBits { n: 1024 });
        // Never consumed: producer blocks on the full channel until drop.
        let pf = PrefetchDealer::spawn(TtpDealer::new(1, 0, 2), sched.clone(), true);
        drop(pf);
        // Partially consumed, then cancelled mid-cycle.
        let mut pf = PrefetchDealer::spawn(TtpDealer::new(1, 0, 2), sched, true);
        pf.wait_warm();
        let mut a = vec![0u64; 1024];
        let mut b = vec![0u64; 1024];
        let mut c = vec![0u64; 1024];
        pf.arith_triples_into(&mut a, &mut b, &mut c).unwrap();
        drop(pf);
        // Empty schedule: warm immediately, every draw is a fallback.
        let mut pf = PrefetchDealer::spawn(TtpDealer::new(1, 0, 2), TripleSchedule::new(), false);
        pf.wait_warm();
        pf.dabits_into(&mut a[..2], &mut b[..2]).unwrap();
        assert_eq!(pf.stats().fallback_ops, 1);
    }
}

// Loom interleaving models (DESIGN.md §8): compiled only under
// `RUSTFLAGS="--cfg loom"`, run with `cargo test --lib -- loom_models`.
// `std::sync::mpsc`'s internals cannot be loom-instrumented, so the models
// check the prefetch *protocol* — a bounded LOOKAHEAD-slot hand-off with
// close-to-cancel, rebuilt from loom's Mutex/Condvar — rather than the std
// channel object itself; the real channel plumbing is covered by the std
// tests above and the nightly TSan CI job.
#[cfg(all(test, loom))]
mod loom_models {
    use loom::sync::{Arc, Condvar, Mutex};
    use loom::thread;
    use std::collections::VecDeque;

    /// The hand-off discipline `PrefetchDealer` relies on, reduced to its
    /// synchronization skeleton: a bounded queue (capacity = `LOOKAHEAD`)
    /// where closing from the consumer side must unpark a producer blocked
    /// on a full slot (what `Drop for PrefetchDealer` does by dropping the
    /// receiver before joining).
    struct Slot {
        state: Mutex<SlotState>,
        not_full: Condvar,
        not_empty: Condvar,
        cap: usize,
    }

    struct SlotState {
        queue: VecDeque<u64>,
        closed: bool,
    }

    impl Slot {
        fn new(cap: usize) -> Arc<Slot> {
            Arc::new(Slot {
                state: Mutex::new(SlotState { queue: VecDeque::new(), closed: false }),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap,
            })
        }

        /// Producer side of `SyncSender::send`: park while full, fail once
        /// the consumer has closed the channel.
        fn send(&self, v: u64) -> Result<(), ()> {
            let mut st = self.state.lock().unwrap();
            while st.queue.len() == self.cap && !st.closed {
                st = self.not_full.wait(st).unwrap();
            }
            if st.closed {
                return Err(());
            }
            st.queue.push_back(v);
            self.not_empty.notify_one();
            Ok(())
        }

        /// Consumer side of `Receiver::recv`: park while empty, `None`
        /// once closed and drained.
        fn recv(&self) -> Option<u64> {
            let mut st = self.state.lock().unwrap();
            loop {
                if let Some(v) = st.queue.pop_front() {
                    self.not_full.notify_one();
                    return Some(v);
                }
                if st.closed {
                    return None;
                }
                st = self.not_empty.wait(st).unwrap();
            }
        }

        /// What `Drop for PrefetchDealer` effects: close and wake both
        /// sides.
        fn close(&self) {
            let mut st = self.state.lock().unwrap();
            st.closed = true;
            self.not_full.notify_one();
            self.not_empty.notify_one();
        }
    }

    /// Every value crosses the bounded hand-off in stream order under all
    /// interleavings — the property that makes prefetched material
    /// bit-identical to inline expansion.
    #[test]
    fn bounded_handoff_preserves_stream_order() {
        loom::model(|| {
            let slot = Slot::new(super::LOOKAHEAD);
            let prod = Arc::clone(&slot);
            let h = thread::spawn(move || {
                for v in 0..3 {
                    prod.send(v).unwrap();
                }
                prod.close();
            });
            assert_eq!(slot.recv(), Some(0));
            assert_eq!(slot.recv(), Some(1));
            assert_eq!(slot.recv(), Some(2));
            assert_eq!(slot.recv(), None);
            h.join().unwrap();
        });
    }

    /// Cancelling must unpark a producer blocked on the full hand-off
    /// slot — otherwise `Drop for PrefetchDealer` would deadlock joining a
    /// producer parked forever in `send`. The model fails by hang (missed
    /// wakeup) if `close` does not notify `not_full`.
    #[test]
    fn cancel_unparks_producer_blocked_on_full_slot() {
        loom::model(|| {
            let slot = Slot::new(1);
            let prod = Arc::clone(&slot);
            let h = thread::spawn(move || {
                let mut sent = 0u64;
                while prod.send(sent).is_ok() {
                    sent += 1;
                    if sent > 4 {
                        break;
                    }
                }
                sent
            });
            // Take one value so the producer advances, then cancel while
            // it is (possibly) parked on the refilled slot.
            assert_eq!(slot.recv(), Some(0));
            slot.close();
            let sent = h.join().unwrap();
            assert!(sent >= 1);
        });
    }
}
