//! End-to-end driver (the repo's headline validation run, recorded in
//! EXPERIMENTS.md): boots the batching private-inference service on a real
//! trained model, serves a stream of requests through the full
//! three-layer stack (Rust coordinator → GMW engine → PJRT-compiled
//! Pallas/JAX artifacts), verifies predictions against plaintext
//! inference, and reports throughput, latency, communication and the
//! paper's network projections.
//!
//! Run: `cargo run --release --example e2e_serve -- [model] [samples]`
//! (defaults: miniresnet_synth10, 64 samples; requires `make artifacts`
//! and `make train` outputs)

use hummingbird::coordinator::{Coordinator, ServeOptions};
use hummingbird::hummingbird::PlanSet;
use hummingbird::model::{Archive, Backend, Dataset, ModelConfig, PlainExecutor};
use hummingbird::net::profile::{project, ComputeProfile, NetworkProfile};
use hummingbird::util::stats;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("miniresnet_synth10");
    let samples: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));

    let cfg = ModelConfig::load_named(&root, model)?;
    let dataset = Dataset::load(root.join("artifacts"), &cfg.dataset)?;
    let weights = Archive::load(root.join("artifacts/weights").join(model))?;

    // Use a searched plan if one exists, else the exact baseline.
    let plan_path = root.join("configs/searched").join(format!("{model}_b8-64.json"));
    let (plan, plan_name) = if plan_path.exists() {
        (PlanSet::load(&plan_path)?, "searched HummingBird-8/64")
    } else {
        (PlanSet::baseline(cfg.relu_groups), "baseline (run `make plans` for HummingBird)")
    };

    println!("=== end-to-end private inference: {model} ===");
    println!("plan: {plan_name} [{}]", plan.summary());
    let mut opts = ServeOptions::new(&root, model);
    opts.plan = Some(plan.clone());
    let svc = Coordinator::start(opts)?;

    // Plaintext reference for verification.
    let plain = PlainExecutor::new(cfg.clone(), weights, Backend::Naive);

    let n = samples.min(dataset.test.n);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push((i, svc.infer_async(dataset.test.batch(i, i + 1).to_vec())?));
    }
    let mut correct = 0usize;
    let mut agree_plain = 0usize;
    let mut latencies = Vec::new();
    for (i, rx) in rxs {
        let r = rx.recv()??;
        let label = dataset.test.labels[i] as usize;
        let plain_logits = plain.forward(dataset.test.batch(i, i + 1), 1)?;
        let plain_pred = PlainExecutor::argmax(&plain_logits, cfg.num_classes)[0];
        correct += (r.pred == label) as usize;
        agree_plain += (r.pred == plain_pred) as usize;
        latencies.push(r.latency_s);
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\nserved {n} private inferences in {}", stats::fmt_secs(wall));
    println!("throughput (this CPU):   {:.2} samples/s", n as f64 / wall);
    println!("accuracy:                {:.2}%", 100.0 * correct as f64 / n as f64);
    println!("agreement w/ plaintext:  {:.2}%", 100.0 * agree_plain as f64 / n as f64);
    println!(
        "p50 / p95 latency:       {} / {}",
        stats::fmt_secs(stats::median(&latencies)),
        stats::fmt_secs(stats::percentile(&latencies, 95.0))
    );
    println!(
        "communication (party 0): {} in {} rounds",
        stats::fmt_bytes(svc.trace.total_bytes()),
        svc.trace.total_rounds()
    );

    let bd = svc.metrics.breakdown();
    println!(
        "\nexecutor breakdown: linear {}, relu {}, other {}",
        stats::fmt_secs(bd.linear_s),
        stats::fmt_secs(bd.relu_s),
        stats::fmt_secs(bd.other_s)
    );

    println!("\nprojected end-to-end time on the paper's network setups:");
    for net in [NetworkProfile::high_bw(), NetworkProfile::lan(), NetworkProfile::wan()] {
        let p = project(&svc.trace, bd.total(), &net, &ComputeProfile::a100());
        println!(
            "  {:8} {:>12}  ({} comm + {} compute)",
            p.network,
            stats::fmt_secs(p.total_s()),
            stats::fmt_secs(p.comm_time_s),
            stats::fmt_secs(p.compute_time_s)
        );
    }
    svc.shutdown();
    println!("\nOK — full stack (coordinator → GMW → PJRT/Pallas artifacts) verified.");
    Ok(())
}
