//! Local-compute kernels of the GMW engine.
//!
//! Every *local* tensor computation the protocol performs between
//! communication rounds is factored behind [`KernelBackend`], with three
//! implementations:
//!
//! * [`RustKernels`] — portable Rust, **lane-per-u64** layout (one w-bit
//!   value in the low bits of each u64). The reference implementation
//!   every test validates against. It splits large lane ranges across OS
//!   threads via `util::threadpool` (the engine's `--threads` knob); small
//!   tensors always run inline, so dispatch overhead never dominates.
//! * [`BitslicedKernels`] — portable Rust, **bit-plane** layout (64 lanes
//!   per word, see [`super::bitsliced`]): every binary-share buffer the
//!   engine hands these kernels holds `w` bit-plane words per 64-lane
//!   block, so one AND instruction processes 64 lanes and the plain `u64`
//!   loops autovectorize. Selected with `--layout bitsliced`; pinned
//!   bit-identical (outputs *and* wire bytes) against [`RustKernels`].
//! * `runtime::XlaKernels` — the same five primitives lowered from the
//!   Layer-1 **Pallas kernels** (`python/compile/kernels/bitops.py`) to HLO
//!   and executed on the PJRT CPU client (lane-per-u64 layout). This is the
//!   path that proves the three-layer composition, and the one a TPU/GPU
//!   deployment would use.
//!
//! The five primitives map 1:1 onto the Pallas kernels and onto the
//! protocol's communication structure: each `*_open` produces exactly the
//! masked values that go on the wire, and each `*_combine` consumes exactly
//! what came back.
//!
//! # Layout contract
//!
//! [`KernelBackend::bin_layout`] declares how the backend interprets
//! *binary*-share buffers, and the engine routes data accordingly (see the
//! "Lane layouts" section of the [`super`] module docs). The arithmetic
//! Beaver primitives (`mult_open` / `mult_combine`) are always
//! lane-per-u64 — HummingBird cannot shrink the 64-bit Mult phase, so
//! there is nothing to slice. `and_open` / `and_combine` are pure
//! element-wise boolean ops and therefore layout-agnostic; only
//! `ks_stage_operands` changes meaning (lane shifts become plane-index
//! shifts).
//!
//! # Buffer discipline (zero-allocation hot path)
//!
//! Every primitive writes into a caller-provided `&mut [u64]` instead of
//! returning a `Vec`. The protocol engine checks those buffers out of its
//! [`Arena`](super::arena::Arena) and returns them when the round
//! completes, so steady-state ReLU evaluation allocates nothing per round.
//! Output layouts:
//!
//! * `and_open` / `mult_open`: `out.len() == 2n`, `d` in `out[..n]`,
//!   `e` in `out[n..]`.
//! * `and_combine` / `mult_combine`: `out.len() == n`.
//! * `ks_stage_operands`: `u_out.len() == v_out.len() == halves·n` where
//!   `halves = if last { 1 } else { 2 }`.
//!
//! (`n` counts buffer *words*: lanes in the classic layout, plane words in
//! the bitsliced layout.)
//!
//! # Kernel dispatch (DESIGN.md §11)
//!
//! Orthogonally to the layout, the two portable backends carry a resolved
//! *kernel arm*: scalar (the chunked loops below, always available) or the
//! explicit AVX2 loops in [`super::simd`]. [`KernelChoice`] is the
//! user-facing knob (`--kernel scalar|simd|auto`, `HB_KERNEL` env
//! override); resolution happens **once at construction**, so the hot
//! loops test a plain `bool`. Both arms are bit-identical — pinned by
//! [`selfcheck`] at coordinator boot and by `tests/kernel_diff.rs`.

use std::sync::OnceLock;

use crate::error::{Error, Result};
use crate::util::threadpool::par_chunks_mut;
use crate::util::tuning;

use super::bitsliced;
use super::simd;

/// How a kernel backend lays out binary-share vectors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BinLayout {
    /// One w-bit lane in the low bits of each u64 (the classic layout).
    #[default]
    LanePerU64,
    /// 64 lanes per word as w bit-planes per block (`gmw::bitsliced`).
    Bitsliced,
}

impl BinLayout {
    /// Stable label for CLI values, metrics and bench row names.
    pub fn label(&self) -> &'static str {
        match self {
            BinLayout::LanePerU64 => "lane",
            BinLayout::Bitsliced => "bitsliced",
        }
    }
}

impl std::fmt::Display for BinLayout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for BinLayout {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "lane" | "lanes" | "lane-per-u64" | "classic" => Ok(BinLayout::LanePerU64),
            "bitsliced" | "bitslice" | "planes" => Ok(BinLayout::Bitsliced),
            other => Err(format!("unknown layout '{other}' (expected 'lane' or 'bitsliced')")),
        }
    }
}

/// Which kernel arm the portable backends run (DESIGN.md §11): the
/// `--kernel` CLI knob. `Auto` (the default) takes the AVX2 arm exactly
/// when the CPU supports it; `Scalar` forces the portable loops; `Simd`
/// *demands* AVX2 (construction fails without it, see
/// [`RustKernels::with_kernel`]). The `HB_KERNEL` environment variable,
/// when set to a parseable value, overrides every programmatic choice —
/// that is how CI re-runs the whole suite with the AVX2 arm pinned off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// Portable chunked loops only.
    Scalar,
    /// Explicit AVX2 loops ([`super::simd`]); an error where unsupported.
    Simd,
    /// Runtime detection: AVX2 when available, scalar otherwise.
    #[default]
    Auto,
}

impl KernelChoice {
    /// Stable label for CLI values, metrics and bench row names.
    pub fn label(&self) -> &'static str {
        match self {
            KernelChoice::Scalar => "scalar",
            KernelChoice::Simd => "simd",
            KernelChoice::Auto => "auto",
        }
    }

    /// The parsed `HB_KERNEL` override, if any (read once per process;
    /// unparseable values are ignored so a typo degrades to the
    /// programmatic choice rather than poisoning every constructor).
    pub fn env_override() -> Option<KernelChoice> {
        static PARSED: OnceLock<Option<KernelChoice>> = OnceLock::new();
        *PARSED.get_or_init(|| tuning::kernel_override().and_then(|raw| raw.parse().ok()))
    }

    /// This choice with the `HB_KERNEL` override applied (the override
    /// wins so one env var can pin an entire test run to one arm).
    pub fn effective(self) -> KernelChoice {
        Self::env_override().unwrap_or(self)
    }

    /// Resolve to the dispatch flag the kernels store: `true` = AVX2 arm.
    /// `Simd` without hardware support degrades to `false` here — use
    /// [`KernelChoice::require`] first where that should be an error.
    pub fn resolve_simd(self) -> bool {
        match self.effective() {
            KernelChoice::Scalar => false,
            KernelChoice::Simd | KernelChoice::Auto => simd::available(),
        }
    }

    /// Fail fast when the *effective* choice demands AVX2 on a machine
    /// without it (typed [`Error::Kernel`], surfaced at CLI parse /
    /// coordinator boot rather than as a silent scalar fallback).
    pub fn require(self) -> Result<()> {
        if self.effective() == KernelChoice::Simd && !simd::available() {
            return Err(Error::kernel(
                "kernel 'simd' requested but AVX2 is not available on this CPU \
                 (use --kernel auto for runtime fallback)",
            ));
        }
        Ok(())
    }
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelChoice::Scalar),
            "simd" | "avx2" => Ok(KernelChoice::Simd),
            "auto" => Ok(KernelChoice::Auto),
            other => {
                Err(format!("unknown kernel '{other}' (expected 'scalar', 'simd' or 'auto')"))
            }
        }
    }
}

/// The dispatch flag an `Auto` construction resolves to right now — the
/// arm that legacy entry points without a backend in scope (e.g. the wire
/// helpers' non-`_with` wrappers) use. Honors `HB_KERNEL`.
pub fn auto_simd() -> bool {
    KernelChoice::Auto.resolve_simd()
}

/// Masked-open / combine primitives for one party.
///
/// Deliberately NOT `Send`: the PJRT client (XLA backend) is thread-local,
/// so each party thread constructs its own backend in-thread (see
/// `gmw::harness::run_parties_with`).
#[allow(clippy::too_many_arguments)]
pub trait KernelBackend {
    /// Beaver-AND open: given share vectors u, v and triple shares a, b
    /// (same layout), write the concatenated masked opening
    /// `d || e` = `(u ⊕ a) || (v ⊕ b)` into `out` (length 2n).
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64], out: &mut [u64]);

    /// Beaver-AND combine: given *public* opened d, e and triple shares
    /// a, b, c, write this party's share of u ∧ v into `out` (length n):
    /// `z = [leader] d∧e ⊕ d∧b ⊕ e∧a ⊕ c`.
    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    );

    /// One Kogge–Stone stage's local prep: from prefix state (g, p) write
    /// the two AND operand vectors for this stage into `u_out` / `v_out`:
    /// `u = p || p`, `v = (g ≪ s) || (p ≪ s)` (shifts within each w-bit
    /// lane, masked to w bits — a plane-index shift in the bitsliced
    /// layout). `last` skips the `p` half (the final stage only needs g),
    /// halving the operand lengths.
    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    );

    /// Beaver arithmetic-multiply open: write `d || e` = `(x − a) || (y − b)`
    /// over Z/2^64 into `out` (length 2n). Always lane-per-u64.
    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]);

    /// Beaver arithmetic-multiply combine: write
    /// `z = c + d·b + e·a + [leader] d·e` over Z/2^64 into `out` (length n).
    /// Always lane-per-u64.
    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    );

    /// Thread-count knob for backends that parallelize across lanes
    /// (no-op by default; the XLA backend parallelizes inside PJRT).
    fn set_threads(&mut self, _threads: usize) {}

    /// Layout this backend expects for binary-share buffers. The engine
    /// routes adder/DReLU data (and the wire boundary) accordingly.
    fn bin_layout(&self) -> BinLayout {
        BinLayout::LanePerU64
    }

    /// Whether this backend's resolved kernel arm is the AVX2 one
    /// (DESIGN.md §11). The engine threads this flag to the wire
    /// pack/unpack paths, so a forced-scalar backend is scalar
    /// end-to-end. Backends without an explicit SIMD arm report `false`.
    fn simd(&self) -> bool {
        false
    }

    /// Human-readable backend name (for metrics / bench labels).
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Shared element-wise inner loops.
//
// Both portable backends funnel into these. Each boolean loop carries a
// `simd` flag: when set (and the buffer clears the
// `tuning::simd_min_words` floor) the explicit AVX2 arm in `gmw::simd`
// runs; otherwise — and always for the wrapping-arithmetic Mult loops,
// which AVX2 cannot express (no 64×64-bit lane multiply) — the scalar
// body below runs. The scalar loops process fixed-size chunks with exact
// trip counts so LLVM unrolls and autovectorizes them (SSE2) even
// without the explicit arm. Both arms are bit-exact with the obvious
// per-element loop.
// ---------------------------------------------------------------------------

/// Elements per vectorization chunk (4 × u64 = one AVX2 register, ×2 for
/// unrolling headroom).
const CHUNK: usize = 8;

/// Whether the AVX2 arm should handle an `n`-word boolean loop.
#[inline]
fn simd_engaged(simd: bool, n: usize) -> bool {
    simd && n >= tuning::simd_min_words()
}

#[inline]
fn xor_into(out: &mut [u64], x: &[u64], y: &[u64], simd: bool) {
    let n = out.len();
    debug_assert!(x.len() == n && y.len() == n);
    if simd_engaged(simd, n) && simd::xor_into(out, x, y) {
        return;
    }
    let main = n - n % CHUNK;
    for ((o, xs), ys) in out[..main]
        .chunks_exact_mut(CHUNK)
        .zip(x[..main].chunks_exact(CHUNK))
        .zip(y[..main].chunks_exact(CHUNK))
    {
        for i in 0..CHUNK {
            o[i] = xs[i] ^ ys[i];
        }
    }
    for i in main..n {
        out[i] = x[i] ^ y[i];
    }
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn and_combine_into(
    out: &mut [u64],
    d: &[u64],
    e: &[u64],
    a: &[u64],
    b: &[u64],
    c: &[u64],
    leader: bool,
    simd: bool,
) {
    let n = out.len();
    debug_assert!(d.len() == n && e.len() == n && a.len() == n && b.len() == n && c.len() == n);
    if simd_engaged(simd, n) && simd::and_combine_into(out, d, e, a, b, c, leader) {
        return;
    }
    if leader {
        for i in 0..n {
            out[i] = (d[i] & e[i]) ^ (d[i] & b[i]) ^ (e[i] & a[i]) ^ c[i];
        }
    } else {
        for i in 0..n {
            out[i] = (d[i] & b[i]) ^ (e[i] & a[i]) ^ c[i];
        }
    }
}

#[inline]
fn sub_wrapping_into(out: &mut [u64], x: &[u64], y: &[u64]) {
    let n = out.len();
    debug_assert!(x.len() == n && y.len() == n);
    let main = n - n % CHUNK;
    for ((o, xs), ys) in out[..main]
        .chunks_exact_mut(CHUNK)
        .zip(x[..main].chunks_exact(CHUNK))
        .zip(y[..main].chunks_exact(CHUNK))
    {
        for i in 0..CHUNK {
            o[i] = xs[i].wrapping_sub(ys[i]);
        }
    }
    for i in main..n {
        out[i] = x[i].wrapping_sub(y[i]);
    }
}

#[inline]
fn mult_combine_into(
    out: &mut [u64],
    d: &[u64],
    e: &[u64],
    a: &[u64],
    b: &[u64],
    c: &[u64],
    leader: bool,
) {
    let n = out.len();
    debug_assert!(d.len() == n && e.len() == n && a.len() == n && b.len() == n && c.len() == n);
    // The leader branch is hoisted out of the loops so each body is a
    // straight-line fused multiply-add chain over wrapping u64s.
    let main = n - n % CHUNK;
    if leader {
        for i0 in (0..main).step_by(CHUNK) {
            for i in i0..i0 + CHUNK {
                out[i] = c[i]
                    .wrapping_add(d[i].wrapping_mul(b[i]))
                    .wrapping_add(e[i].wrapping_mul(a[i]))
                    .wrapping_add(d[i].wrapping_mul(e[i]));
            }
        }
        for i in main..n {
            out[i] = c[i]
                .wrapping_add(d[i].wrapping_mul(b[i]))
                .wrapping_add(e[i].wrapping_mul(a[i]))
                .wrapping_add(d[i].wrapping_mul(e[i]));
        }
    } else {
        for i0 in (0..main).step_by(CHUNK) {
            for i in i0..i0 + CHUNK {
                out[i] = c[i]
                    .wrapping_add(d[i].wrapping_mul(b[i]))
                    .wrapping_add(e[i].wrapping_mul(a[i]));
            }
        }
        for i in main..n {
            out[i] = c[i]
                .wrapping_add(d[i].wrapping_mul(b[i]))
                .wrapping_add(e[i].wrapping_mul(a[i]));
        }
    }
}

/// Shared threaded implementations of the layout-agnostic primitives
/// (element-wise over whatever words the layout stores).
#[inline]
fn threaded_and_open(
    t: usize,
    simd: bool,
    u: &[u64],
    v: &[u64],
    a: &[u64],
    b: &[u64],
    out: &mut [u64],
) {
    let n = u.len();
    debug_assert!(v.len() == n && a.len() == n && b.len() == n && out.len() == 2 * n);
    let (d_out, e_out) = out.split_at_mut(n);
    par_chunks_mut(d_out, t, |off, chunk| {
        xor_into(chunk, &u[off..off + chunk.len()], &a[off..off + chunk.len()], simd);
    });
    par_chunks_mut(e_out, t, |off, chunk| {
        xor_into(chunk, &v[off..off + chunk.len()], &b[off..off + chunk.len()], simd);
    });
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn threaded_and_combine(
    t: usize,
    simd: bool,
    d: &[u64],
    e: &[u64],
    a: &[u64],
    b: &[u64],
    c: &[u64],
    leader: bool,
    out: &mut [u64],
) {
    let n = d.len();
    debug_assert!(e.len() == n && a.len() == n && b.len() == n && c.len() == n);
    debug_assert_eq!(out.len(), n);
    par_chunks_mut(out, t, |off, chunk| {
        let hi = off + chunk.len();
        let (d, e) = (&d[off..hi], &e[off..hi]);
        and_combine_into(chunk, d, e, &a[off..hi], &b[off..hi], &c[off..hi], leader, simd);
    });
}

#[inline]
fn threaded_mult_open(t: usize, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
    let n = x.len();
    debug_assert!(y.len() == n && a.len() == n && b.len() == n && out.len() == 2 * n);
    let (d_out, e_out) = out.split_at_mut(n);
    par_chunks_mut(d_out, t, |off, chunk| {
        sub_wrapping_into(chunk, &x[off..off + chunk.len()], &a[off..off + chunk.len()]);
    });
    par_chunks_mut(e_out, t, |off, chunk| {
        sub_wrapping_into(chunk, &y[off..off + chunk.len()], &b[off..off + chunk.len()]);
    });
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn threaded_mult_combine(
    t: usize,
    d: &[u64],
    e: &[u64],
    a: &[u64],
    b: &[u64],
    c: &[u64],
    leader: bool,
    out: &mut [u64],
) {
    let n = d.len();
    debug_assert!(e.len() == n && a.len() == n && b.len() == n && c.len() == n);
    debug_assert_eq!(out.len(), n);
    par_chunks_mut(out, t, |off, chunk| {
        let hi = off + chunk.len();
        let (d, e) = (&d[off..hi], &e[off..hi]);
        mult_combine_into(chunk, d, e, &a[off..hi], &b[off..hi], &c[off..hi], leader);
    });
}

/// Threads to engage for `n` processed words (inline below the tuning
/// threshold so small tensors never pay dispatch overhead).
#[inline]
fn eff_threads(threads: usize, n: usize) -> usize {
    if n >= tuning::par_min_lanes() {
        threads
    } else {
        1
    }
}

// ---------------------------------------------------------------------------
// Lane-per-u64 reference backend.
// ---------------------------------------------------------------------------

/// Portable Rust implementation, lane-per-u64 layout, optionally
/// multi-threaded across lanes. Carries a resolved kernel arm
/// (DESIGN.md §11): `Default` and [`with_threads`](Self::with_threads)
/// resolve [`KernelChoice::Auto`], so every existing construction site
/// picks up AVX2 where the CPU has it (and `HB_KERNEL=scalar` pins the
/// whole process back to the portable loops).
#[derive(Debug, Clone)]
pub struct RustKernels {
    threads: usize,
    simd: bool,
}

impl Default for RustKernels {
    fn default() -> Self {
        RustKernels { threads: 1, simd: KernelChoice::Auto.resolve_simd() }
    }
}

impl RustKernels {
    /// Kernels that split lane ranges across up to `threads` OS threads
    /// (only engaged above [`tuning::par_min_lanes`] lanes).
    pub fn with_threads(threads: usize) -> Self {
        RustKernels { threads: threads.max(1), simd: KernelChoice::Auto.resolve_simd() }
    }

    /// Kernels with an explicit arm choice. Fails (typed
    /// [`Error::Kernel`]) when the effective choice is
    /// [`KernelChoice::Simd`] on a CPU without AVX2.
    pub fn with_kernel(choice: KernelChoice) -> Result<Self> {
        choice.require()?;
        Ok(RustKernels { threads: 1, simd: choice.resolve_simd() })
    }

    /// The always-available reference arm: portable loops, regardless of
    /// CPU, CLI or `HB_KERNEL`. This is what [`selfcheck`] and the
    /// differential tests compare the dispatched arm against.
    pub fn scalar() -> Self {
        RustKernels { threads: 1, simd: false }
    }
}

#[allow(clippy::too_many_arguments)]
impl KernelBackend for RustKernels {
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        threaded_and_open(eff_threads(self.threads, u.len()), self.simd, u, v, a, b, out);
    }

    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        let t = eff_threads(self.threads, d.len());
        threaded_and_combine(t, self.simd, d, e, a, b, c, leader, out);
    }

    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    ) {
        let mask = crate::ring::low_mask(w);
        let n = g.len();
        let halves = if last { 1 } else { 2 };
        debug_assert!(p.len() == n && u_out.len() == halves * n && v_out.len() == halves * n);
        let t = eff_threads(self.threads, n);
        let simd = self.simd;
        par_chunks_mut(&mut u_out[..n], t, |off, chunk| {
            chunk.copy_from_slice(&p[off..off + chunk.len()]);
        });
        par_chunks_mut(&mut v_out[..n], t, |off, chunk| {
            if simd_engaged(simd, chunk.len()) && simd::shl_mask_into(chunk, &g[off..], s, mask)
            {
                return;
            }
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = (g[off + i] << s) & mask;
            }
        });
        if !last {
            par_chunks_mut(&mut u_out[n..], t, |off, chunk| {
                chunk.copy_from_slice(&p[off..off + chunk.len()]);
            });
            par_chunks_mut(&mut v_out[n..], t, |off, chunk| {
                if simd_engaged(simd, chunk.len())
                    && simd::shl_mask_into(chunk, &p[off..], s, mask)
                {
                    return;
                }
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = (p[off + i] << s) & mask;
                }
            });
        }
    }

    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        threaded_mult_open(eff_threads(self.threads, x.len()), x, y, a, b, out);
    }

    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        threaded_mult_combine(eff_threads(self.threads, d.len()), d, e, a, b, c, leader, out);
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    fn simd(&self) -> bool {
        self.simd
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

// ---------------------------------------------------------------------------
// Bitsliced backend.
// ---------------------------------------------------------------------------

/// Portable Rust implementation over bit-plane buffers: binary primitives
/// process 64 lanes per word (see [`super::bitsliced`] for the layout).
/// The arithmetic primitives are the same chunked lane-per-u64 loops as
/// [`RustKernels`] — the 64-bit Mult phase has nothing to slice.
///
/// The element-wise binary primitives (`and_open` / `and_combine`) reuse
/// the shared loops above: XOR/AND are position-wise, so the same code is
/// correct in either layout — only the word count changes (`n·w/64`-ish
/// plane words instead of `n` lanes). `ks_stage_operands` is where the
/// layouts genuinely diverge: the per-lane `(x ≪ s) & mask` becomes a
/// plane-index shift with the mask implicit.
#[derive(Debug, Clone)]
pub struct BitslicedKernels {
    threads: usize,
    simd: bool,
}

impl Default for BitslicedKernels {
    fn default() -> Self {
        BitslicedKernels { threads: 1, simd: KernelChoice::Auto.resolve_simd() }
    }
}

impl BitslicedKernels {
    /// Bitsliced kernels with a lane-parallelism budget of `threads`.
    pub fn with_threads(threads: usize) -> Self {
        BitslicedKernels { threads: threads.max(1), simd: KernelChoice::Auto.resolve_simd() }
    }

    /// Bitsliced kernels with an explicit arm choice (see
    /// [`RustKernels::with_kernel`]).
    pub fn with_kernel(choice: KernelChoice) -> Result<Self> {
        choice.require()?;
        Ok(BitslicedKernels { threads: 1, simd: choice.resolve_simd() })
    }

    /// The always-available reference arm (see [`RustKernels::scalar`]).
    pub fn scalar() -> Self {
        BitslicedKernels { threads: 1, simd: false }
    }
}

#[allow(clippy::too_many_arguments)]
impl KernelBackend for BitslicedKernels {
    fn and_open(&mut self, u: &[u64], v: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        threaded_and_open(eff_threads(self.threads, u.len()), self.simd, u, v, a, b, out);
    }

    fn and_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        let t = eff_threads(self.threads, d.len());
        threaded_and_combine(t, self.simd, d, e, a, b, c, leader, out);
    }

    fn ks_stage_operands(
        &mut self,
        g: &[u64],
        p: &[u64],
        s: u32,
        w: u32,
        last: bool,
        u_out: &mut [u64],
        v_out: &mut [u64],
    ) {
        let pl = g.len();
        debug_assert_eq!(pl % w as usize, 0, "plane buffer length must be a block multiple");
        let halves = if last { 1 } else { 2 };
        debug_assert!(p.len() == pl && u_out.len() == halves * pl && v_out.len() == halves * pl);
        let t = eff_threads(self.threads, pl);
        par_chunks_mut(&mut u_out[..pl], t, |off, chunk| {
            chunk.copy_from_slice(&p[off..off + chunk.len()]);
        });
        bitsliced::plane_shl_into(g, w, s, &mut v_out[..pl], t);
        if !last {
            par_chunks_mut(&mut u_out[pl..], t, |off, chunk| {
                chunk.copy_from_slice(&p[off..off + chunk.len()]);
            });
            bitsliced::plane_shl_into(p, w, s, &mut v_out[pl..], t);
        }
    }

    fn mult_open(&mut self, x: &[u64], y: &[u64], a: &[u64], b: &[u64], out: &mut [u64]) {
        threaded_mult_open(eff_threads(self.threads, x.len()), x, y, a, b, out);
    }

    fn mult_combine(
        &mut self,
        d: &[u64],
        e: &[u64],
        a: &[u64],
        b: &[u64],
        c: &[u64],
        leader: bool,
        out: &mut [u64],
    ) {
        threaded_mult_combine(eff_threads(self.threads, d.len()), d, e, a, b, c, leader, out);
    }

    fn set_threads(&mut self, threads: usize) {
        self.threads = threads.max(1);
    }

    fn bin_layout(&self) -> BinLayout {
        BinLayout::Bitsliced
    }

    fn simd(&self) -> bool {
        self.simd
    }

    fn name(&self) -> &'static str {
        "bitsliced"
    }
}

// ---------------------------------------------------------------------------
// Boot-time kernel cross-check.
// ---------------------------------------------------------------------------

/// Cross-check the dispatched kernel arm against the forced-scalar
/// reference on deterministic inputs — every boolean primitive, the
/// Kogge–Stone operand builder in both layouts, the 64×64 transpose and
/// the fused wire pack/unpack (DESIGN.md §11). Returns a typed
/// [`Error::Kernel`] naming the first diverging primitive, so a broken
/// SIMD arm (miscompile, unexpected CPU behaviour) fails fast at
/// coordinator boot or `selftest` instead of silently serving wrong
/// shares. Cost is a few thousand word-ops — noise at boot.
pub fn selfcheck(choice: KernelChoice) -> Result<()> {
    choice.require()?;
    let mismatch = |what: &str| {
        Error::kernel(format!(
            "kernel selfcheck: '{}' arm diverges from scalar reference in {what}",
            choice.effective().label()
        ))
    };
    let n = tuning::simd_min_words().max(8) * 40 + 7; // odd: exercise tails
    let mut prg = crate::crypto::prg::Prg::new(0x5E1F, 0xC8EC);
    let (u, v) = (prg.vec_u64(n), prg.vec_u64(n));
    let (a, b, c) = (prg.vec_u64(n), prg.vec_u64(n), prg.vec_u64(n));
    let w = 20u32;
    let mask = crate::ring::low_mask(w);
    // HOT-PATH-ALLOW: boot-only selfcheck scratch, never on the round path.
    let g: Vec<u64> = u.iter().map(|x| x & mask).collect();
    let p: Vec<u64> = v.iter().map(|x| x & mask).collect();

    let mut dut = RustKernels::with_kernel(choice)?;
    let mut reference = RustKernels::scalar();
    // HOT-PATH-ALLOW: boot-only selfcheck scratch, never on the round path.
    let mut out_d = vec![0u64; 2 * n];
    let mut out_r = vec![0u64; 2 * n];
    dut.and_open(&u, &v, &a, &b, &mut out_d);
    reference.and_open(&u, &v, &a, &b, &mut out_r);
    if out_d != out_r {
        return Err(mismatch("and_open"));
    }
    for leader in [false, true] {
        // HOT-PATH-ALLOW: boot-only selfcheck scratch.
        let mut z_d = vec![0u64; n];
        let mut z_r = vec![0u64; n];
        dut.and_combine(&u, &v, &a, &b, &c, leader, &mut z_d);
        reference.and_combine(&u, &v, &a, &b, &c, leader, &mut z_r);
        if z_d != z_r {
            return Err(mismatch("and_combine"));
        }
    }
    for (s, last) in [(1u32, false), (8, true)] {
        let halves = if last { 1 } else { 2 };
        // HOT-PATH-ALLOW: boot-only selfcheck scratch.
        let mut ud = vec![0u64; halves * n];
        let mut vd = vec![0u64; halves * n];
        // HOT-PATH-ALLOW: boot-only selfcheck scratch.
        let mut ur = vec![0u64; halves * n];
        let mut vr = vec![0u64; halves * n];
        dut.ks_stage_operands(&g, &p, s, w, last, &mut ud, &mut vd);
        reference.ks_stage_operands(&g, &p, s, w, last, &mut ur, &mut vr);
        if ud != ur || vd != vr {
            return Err(mismatch("ks_stage_operands"));
        }
    }

    // The bitsliced side: transpose + the fused wire boundary, dispatched
    // vs forced-scalar.
    let simd = choice.resolve_simd();
    let nl = 130usize; // two full blocks + a ragged tail block
    // HOT-PATH-ALLOW: boot-only selfcheck scratch, never on the round path.
    let lanes: Vec<u64> = g.iter().take(nl).copied().collect();
    let mut planes = vec![0u64; bitsliced::plane_len(nl, w)];
    bitsliced::lanes_to_planes(&lanes, w, &mut planes, 1);
    let nbytes = crate::bitpack::packed_bytes(nl, w) as usize;
    // HOT-PATH-ALLOW: boot-only selfcheck scratch, never on the round path.
    let mut wire_d = vec![0u8; nbytes];
    let mut wire_r = vec![0u8; nbytes];
    bitsliced::pack_planes_xor_into_with(&planes, w, nl, 0, &mut wire_d, 1, simd);
    bitsliced::pack_planes_xor_into_with(&planes, w, nl, 0, &mut wire_r, 1, false);
    if wire_d != wire_r {
        return Err(mismatch("pack_planes_xor_into"));
    }
    // HOT-PATH-ALLOW: boot-only selfcheck scratch, never on the round path.
    let mut back_d = vec![0u64; planes.len()];
    let mut back_r = vec![0u64; planes.len()];
    bitsliced::unpack_bytes_xor_into_planes_with(&wire_d, w, nl, 0, &mut back_d, 1, simd);
    bitsliced::unpack_bytes_xor_into_planes_with(&wire_r, w, nl, 0, &mut back_r, 1, false);
    if back_d != back_r || back_d != planes {
        return Err(mismatch("unpack_bytes_xor_into_planes"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::prg::Prg;
    use crate::gmw::bitsliced::{lanes_to_planes, plane_len, planes_to_lanes};

    /// One-party-world sanity: with "shares" equal to plaintext and a zero
    /// triple, open/combine reduce to plain AND / MUL.
    #[test]
    fn degenerate_open_combine_is_plain_and() {
        let mut k = RustKernels::default();
        let u = vec![0b1100u64];
        let v = vec![0b1010u64];
        let zero = vec![0u64];
        let mut de = vec![0u64; 2];
        k.and_open(&u, &v, &zero, &zero, &mut de);
        assert_eq!(de, vec![0b1100, 0b1010]);
        let mut z = vec![0u64; 1];
        k.and_combine(&de[..1], &de[1..], &zero, &zero, &zero, true, &mut z);
        assert_eq!(z, vec![0b1000]);
    }

    #[test]
    fn degenerate_mult_is_plain_mul() {
        let mut k = RustKernels::default();
        let x = vec![7u64];
        let y = vec![6u64.wrapping_neg()]; // -6
        let zero = vec![0u64];
        let mut de = vec![0u64; 2];
        k.mult_open(&x, &y, &zero, &zero, &mut de);
        let mut z = vec![0u64; 1];
        k.mult_combine(&de[..1], &de[1..], &zero, &zero, &zero, true, &mut z);
        assert_eq!(z[0] as i64, -42);
    }

    #[test]
    fn stage_operands_shift_and_mask() {
        let mut k = RustKernels::default();
        let g = vec![0b1000u64];
        let p = vec![0b1111u64];
        let (mut u, mut v) = (vec![0u64; 2], vec![0u64; 2]);
        k.ks_stage_operands(&g, &p, 1, 4, false, &mut u, &mut v);
        assert_eq!(u, vec![0b1111, 0b1111]);
        assert_eq!(v, vec![0b0000, 0b1110]); // g<<1 overflows the 4-bit lane
        let (mut u, mut v) = (vec![0u64; 1], vec![0u64; 1]);
        k.ks_stage_operands(&g, &p, 2, 6, true, &mut u, &mut v);
        assert_eq!(u, vec![0b1111]);
        assert_eq!(v, vec![0b100000]);
    }

    /// The bitsliced stage-operand builder agrees with the classic one
    /// through the transpose, for every stage shape.
    #[test]
    fn bitsliced_stage_operands_match_classic_through_transpose() {
        let mut classic = RustKernels::default();
        let mut sliced = BitslicedKernels::default();
        for w in [2u32, 6, 8, 20, 64] {
            let n = 100usize;
            let mask = crate::ring::low_mask(w);
            let mut prg = Prg::new(w as u64, 9);
            let g: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
            let p: Vec<u64> = (0..n).map(|_| prg.next_u64() & mask).collect();
            let pl = plane_len(n, w);
            let mut gp = vec![0u64; pl];
            let mut pp = vec![0u64; pl];
            lanes_to_planes(&g, w, &mut gp, 1);
            lanes_to_planes(&p, w, &mut pp, 1);
            for (s, last) in [(1u32, false), (2, false), (w.next_power_of_two() / 2, true)] {
                let halves = if last { 1 } else { 2 };
                let mut u1 = vec![0u64; halves * n];
                let mut v1 = vec![0u64; halves * n];
                classic.ks_stage_operands(&g, &p, s, w, last, &mut u1, &mut v1);
                let mut up = vec![0u64; halves * pl];
                let mut vp = vec![0u64; halves * pl];
                sliced.ks_stage_operands(&gp, &pp, s, w, last, &mut up, &mut vp);
                for h in 0..halves {
                    let mut ul = vec![0u64; n];
                    let mut vl = vec![0u64; n];
                    planes_to_lanes(&up[h * pl..(h + 1) * pl], w, n, &mut ul, 1);
                    planes_to_lanes(&vp[h * pl..(h + 1) * pl], w, n, &mut vl, 1);
                    assert_eq!(ul, u1[h * n..(h + 1) * n], "u half {h} w={w} s={s}");
                    assert_eq!(vl, v1[h * n..(h + 1) * n], "v half {h} w={w} s={s}");
                }
            }
        }
    }

    /// The chunked element-wise helpers match the naive per-element loops
    /// at lengths around the chunk boundary.
    #[test]
    fn chunked_helpers_match_naive() {
        let mut prg = Prg::new(77, 1);
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 100] {
            let d = prg.vec_u64(n);
            let e = prg.vec_u64(n);
            let a = prg.vec_u64(n);
            let b = prg.vec_u64(n);
            let c = prg.vec_u64(n);
            let mut out = vec![0u64; n];
            sub_wrapping_into(&mut out, &d, &e);
            let naive: Vec<u64> = d.iter().zip(&e).map(|(x, y)| x.wrapping_sub(*y)).collect();
            assert_eq!(out, naive, "sub n={n}");
            for simd in [false, true] {
                xor_into(&mut out, &d, &e, simd);
                let naive: Vec<u64> = d.iter().zip(&e).map(|(x, y)| x ^ y).collect();
                assert_eq!(out, naive, "xor n={n} simd={simd}");
            }
            for leader in [false, true] {
                mult_combine_into(&mut out, &d, &e, &a, &b, &c, leader);
                let naive: Vec<u64> = (0..n)
                    .map(|i| {
                        let mut z = c[i]
                            .wrapping_add(d[i].wrapping_mul(b[i]))
                            .wrapping_add(e[i].wrapping_mul(a[i]));
                        if leader {
                            z = z.wrapping_add(d[i].wrapping_mul(e[i]));
                        }
                        z
                    })
                    .collect();
                assert_eq!(out, naive, "mult_combine n={n} leader={leader}");
                for simd in [false, true] {
                    and_combine_into(&mut out, &d, &e, &a, &b, &c, leader, simd);
                    let naive: Vec<u64> = (0..n)
                        .map(|i| {
                            let mut z = (d[i] & b[i]) ^ (e[i] & a[i]) ^ c[i];
                            if leader {
                                z ^= d[i] & e[i];
                            }
                            z
                        })
                        .collect();
                    assert_eq!(out, naive, "and_combine n={n} leader={leader} simd={simd}");
                }
            }
        }
    }

    /// Multi-threaded kernels are bit-identical to single-threaded for every
    /// primitive, at a lane count that actually engages the thread pool.
    #[test]
    fn parallel_kernels_match_scalar_reference() {
        let n = tuning::par_min_lanes() + 1000;
        let mut prg = Prg::new(17, 0);
        let u = prg.vec_u64(n);
        let v = prg.vec_u64(n);
        let a = prg.vec_u64(n);
        let b = prg.vec_u64(n);
        let c = prg.vec_u64(n);
        let mut scalar = RustKernels::default();
        for threads in [2usize, 4, crate::util::threadpool::default_threads()] {
            let mut par = RustKernels::with_threads(threads);

            let mut de1 = vec![0u64; 2 * n];
            let mut de2 = vec![0u64; 2 * n];
            scalar.and_open(&u, &v, &a, &b, &mut de1);
            par.and_open(&u, &v, &a, &b, &mut de2);
            assert_eq!(de1, de2, "and_open threads={threads}");

            for leader in [true, false] {
                let mut z1 = vec![0u64; n];
                let mut z2 = vec![0u64; n];
                scalar.and_combine(&u, &v, &a, &b, &c, leader, &mut z1);
                par.and_combine(&u, &v, &a, &b, &c, leader, &mut z2);
                assert_eq!(z1, z2, "and_combine threads={threads}");
                scalar.mult_combine(&u, &v, &a, &b, &c, leader, &mut z1);
                par.mult_combine(&u, &v, &a, &b, &c, leader, &mut z2);
                assert_eq!(z1, z2, "mult_combine threads={threads}");
            }

            scalar.mult_open(&u, &v, &a, &b, &mut de1);
            par.mult_open(&u, &v, &a, &b, &mut de2);
            assert_eq!(de1, de2, "mult_open threads={threads}");

            let w = 20u32;
            let mask = crate::ring::low_mask(w);
            let g: Vec<u64> = u.iter().map(|x| x & mask).collect();
            let p: Vec<u64> = v.iter().map(|x| x & mask).collect();
            for (s, last) in [(1u32, false), (4, true)] {
                let halves = if last { 1 } else { 2 };
                let mut u1 = vec![0u64; halves * n];
                let mut v1 = vec![0u64; halves * n];
                let mut u2 = vec![0u64; halves * n];
                let mut v2 = vec![0u64; halves * n];
                scalar.ks_stage_operands(&g, &p, s, w, last, &mut u1, &mut v1);
                par.ks_stage_operands(&g, &p, s, w, last, &mut u2, &mut v2);
                assert_eq!(u1, u2, "stage u threads={threads} last={last}");
                assert_eq!(v1, v2, "stage v threads={threads} last={last}");
            }
        }
    }

    #[test]
    fn layout_parse_and_labels() {
        assert_eq!("lane".parse::<BinLayout>().unwrap(), BinLayout::LanePerU64);
        assert_eq!("Bitsliced".parse::<BinLayout>().unwrap(), BinLayout::Bitsliced);
        assert_eq!("lane-per-u64".parse::<BinLayout>().unwrap(), BinLayout::LanePerU64);
        assert!("simd".parse::<BinLayout>().is_err());
        assert_eq!(BinLayout::Bitsliced.label(), "bitsliced");
        assert_eq!(RustKernels::default().bin_layout(), BinLayout::LanePerU64);
        assert_eq!(BitslicedKernels::default().bin_layout(), BinLayout::Bitsliced);
    }

    #[test]
    fn kernel_choice_parse_and_labels() {
        assert_eq!("scalar".parse::<KernelChoice>().unwrap(), KernelChoice::Scalar);
        assert_eq!("SIMD".parse::<KernelChoice>().unwrap(), KernelChoice::Simd);
        assert_eq!("avx2".parse::<KernelChoice>().unwrap(), KernelChoice::Simd);
        assert_eq!(" auto ".parse::<KernelChoice>().unwrap(), KernelChoice::Auto);
        assert!("fast".parse::<KernelChoice>().is_err());
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
        assert_eq!(KernelChoice::Simd.label(), "simd");
        assert_eq!(KernelChoice::Auto.to_string(), "auto");
    }

    /// The resolution invariants that hold in *any* environment (with or
    /// without AVX2, with or without an `HB_KERNEL` override): the
    /// reference constructors are scalar, the AVX2 flag implies hardware
    /// support, and `Auto` never fails `require`.
    #[test]
    fn kernel_resolution_invariants() {
        assert!(!RustKernels::scalar().simd());
        assert!(!BitslicedKernels::scalar().simd());
        for k in [RustKernels::default().simd(), RustKernels::with_threads(4).simd()] {
            assert!(!k || super::super::simd::available(), "simd arm without AVX2");
        }
        assert_eq!(RustKernels::default().simd(), auto_simd());
        assert_eq!(BitslicedKernels::default().simd(), auto_simd());
        KernelChoice::Auto.require().expect("auto must always be constructible");
        let forced = RustKernels::with_kernel(KernelChoice::Scalar).unwrap();
        assert_eq!(forced.simd(), KernelChoice::Scalar.resolve_simd());
        // `Simd` either constructs with the arm engaged or fails typed.
        match RustKernels::with_kernel(KernelChoice::Simd) {
            Ok(k) => assert_eq!(k.simd(), KernelChoice::Simd.resolve_simd()),
            Err(e) => {
                assert!(matches!(e, crate::Error::Kernel(_)), "want Error::Kernel, got {e}");
                assert!(!super::super::simd::available() || KernelChoice::env_override().is_some());
            }
        }
    }

    /// The dispatched arm (whatever it resolves to here) passes the boot
    /// cross-check against the forced-scalar reference, in every choice.
    #[test]
    fn selfcheck_passes_for_all_constructible_choices() {
        selfcheck(KernelChoice::Scalar).expect("scalar vs scalar");
        selfcheck(KernelChoice::Auto).expect("auto vs scalar");
        if KernelChoice::Simd.require().is_ok() {
            selfcheck(KernelChoice::Simd).expect("simd vs scalar");
        }
    }

    /// Forced-scalar and dispatched kernels agree on every primitive at
    /// sizes above and below the SIMD floor (the n < floor arm must take
    /// the scalar tail path inside the dispatched kernel too).
    #[test]
    fn scalar_and_dispatched_kernels_agree() {
        let mut prg = Prg::new(0xD15, 7);
        for n in [1usize, tuning::simd_min_words(), 4 * tuning::simd_min_words() + 3] {
            let u = prg.vec_u64(n);
            let v = prg.vec_u64(n);
            let a = prg.vec_u64(n);
            let b = prg.vec_u64(n);
            let c = prg.vec_u64(n);
            let mut auto_k = RustKernels::default();
            let mut scal_k = RustKernels::scalar();
            let mut de1 = vec![0u64; 2 * n];
            let mut de2 = vec![0u64; 2 * n];
            auto_k.and_open(&u, &v, &a, &b, &mut de1);
            scal_k.and_open(&u, &v, &a, &b, &mut de2);
            assert_eq!(de1, de2, "and_open n={n}");
            let mut z1 = vec![0u64; n];
            let mut z2 = vec![0u64; n];
            for leader in [false, true] {
                auto_k.and_combine(&u, &v, &a, &b, &c, leader, &mut z1);
                scal_k.and_combine(&u, &v, &a, &b, &c, leader, &mut z2);
                assert_eq!(z1, z2, "and_combine n={n} leader={leader}");
            }
        }
    }
}
