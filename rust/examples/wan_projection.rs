//! Network sensitivity study (the paper's Fig 9 methodology, exposed as a
//! library example), in two halves:
//!
//! 1. **Analytic projection** — sweep bandwidth/latency over several orders
//!    of magnitude, pricing each plan's recorded trace with
//!    [`NetworkProfile::round_time`], and show where HummingBird's
//!    advantage saturates.
//! 2. **Simulated measurement** — replay the same protocol over a
//!    virtual-clock [`SimTransport`] and print the simulator's elapsed time
//!    next to the closed-form projection, for both the serial and the
//!    overlapped chunked schedule (DESIGN.md §10). The two columns agree,
//!    and overlap removes the per-round latency term.
//!
//! Run: `cargo run --release --example wan_projection`

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::run_parties;
use hummingbird::gmw::{GmwParty, ReluPlan};
use hummingbird::net::local::hub;
use hummingbird::net::profile::NetworkProfile;
use hummingbird::net::sim::SimTransport;
use hummingbird::sharing::share_arith;
use hummingbird::util::stats;

/// One 2-party chunked ReLU with party 0 behind a virtual-time simulated
/// link: seconds on the mock clock, plus party 0's round/byte totals.
fn measure_virtual(
    shares: &[Vec<u64>],
    plan: ReluPlan,
    net: &NetworkProfile,
    chunks: usize,
    overlap: bool,
) -> (f64, u64, u64) {
    let mut ts = hub(2);
    let t1 = ts.pop().unwrap();
    let t0 = ts.pop().unwrap();
    let trace = t0.trace();
    let (sim, mock) = SimTransport::virtual_time(t0, net.clone());
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut p = GmwParty::new(t1, 7);
            p.relu_chunked(&shares[1], plan, chunks, overlap).unwrap();
        });
        let mut p = GmwParty::new(sim, 7);
        p.relu_chunked(&shares[0], plan, chunks, overlap).unwrap();
    });
    (mock.now().as_secs_f64(), trace.total_rounds(), trace.total_bytes())
}

fn main() {
    // Measure one ReLU layer's trace for baseline and HummingBird windows.
    let n = 16384;
    let mut prg = Prg::new(1, 0);
    let x: Vec<u64> = (0..n).map(|_| prg.next_u64() % (1 << 16)).collect();
    let shares = share_arith(&mut prg, &x, 2);

    let mut traces = Vec::new();
    for (name, plan) in [
        ("baseline-64", ReluPlan::BASELINE),
        ("eco-18", ReluPlan::new(18, 0).unwrap()),
        ("hb-8", ReluPlan::new(12, 4).unwrap()),
        ("hb-6", ReluPlan::new(10, 4).unwrap()),
    ] {
        let shares = shares.clone();
        let run = run_parties(2, 7, move |p| {
            let me = p.party();
            p.relu(&shares[me], plan).unwrap();
        });
        let rounds: Vec<u64> = run.trace.rounds().iter().map(|r| r.bytes_sent).collect();
        println!(
            "{name:<12} {:>10} in {} rounds",
            stats::fmt_bytes(run.trace.total_bytes()),
            rounds.len()
        );
        traces.push((name, rounds));
    }

    // Sweep: NVLink-class to congested-WAN-class links.
    let profiles = [
        NetworkProfile::new("NVLink", 5e-6, 16e12),
        NetworkProfile::new("100GbE", 10e-6, 100e9),
        NetworkProfile::lan(),
        NetworkProfile::new("1GbE", 100e-6, 1e9),
        NetworkProfile::wan(),
        NetworkProfile::new("slow-WAN", 50e-3, 50e6),
    ];
    println!("\nprojected time per ReLU layer ({n} elements) and speedup vs baseline:");
    println!(
        "{:<10} {:>12} {:>14} {:>14} {:>14}",
        "network", "baseline-64", "eco-18", "hb-8", "hb-6"
    );
    for net in &profiles {
        let times: Vec<f64> = traces
            .iter()
            .map(|(_, rounds)| rounds.iter().map(|b| net.round_time(*b)).sum())
            .collect();
        println!(
            "{:<10} {:>12} {:>8} ({:4.2}x) {:>7} ({:4.2}x) {:>7} ({:4.2}x)",
            net.name,
            stats::fmt_secs(times[0]),
            stats::fmt_secs(times[1]),
            times[0] / times[1],
            stats::fmt_secs(times[2]),
            times[0] / times[2],
            stats::fmt_secs(times[3]),
            times[0] / times[3],
        );
    }
    println!(
        "\nAs bandwidth shrinks, byte volume dominates round latency and the\n\
         speedup approaches the raw communication reduction — the paper's\n\
         High-BW < LAN < WAN ordering (Fig 9)."
    );

    // Projection vs simulation (DESIGN.md §10): replay the hb-8 plan over a
    // virtual-clock SimTransport and print the simulator's elapsed time
    // next to the closed forms — serial pays `rounds × L + tx`, overlapped
    // pays one latency per lockstep wave, `waves × L + tx`.
    let chunks = 8;
    let plan = ReluPlan::new(12, 4).unwrap();
    println!("\nhb-8 on the virtual clock ({chunks} chunks), projected vs simulated:");
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>12}",
        "network", "proj-serial", "sim-serial", "proj-overlap", "sim-overlap"
    );
    for net in [NetworkProfile::lan(), NetworkProfile::wan()] {
        let (serial_s, rounds, bytes) = measure_virtual(&shares, plan, &net, chunks, false);
        let (overlap_s, _, _) = measure_virtual(&shares, plan, &net, chunks, true);
        let tx = bytes as f64 * 8.0 / net.bandwidth_bps;
        let waves = rounds / chunks as u64;
        let proj_serial = rounds as f64 * net.latency_s + tx;
        let proj_overlap = waves as f64 * net.latency_s + tx;
        println!(
            "{:<10} {:>12} {:>12} {:>14} {:>12}",
            net.name,
            stats::fmt_secs(proj_serial),
            stats::fmt_secs(serial_s),
            stats::fmt_secs(proj_overlap),
            stats::fmt_secs(overlap_s),
        );
    }
    println!(
        "\nSimulated and projected agree; overlapping the chunk rounds removes\n\
         the per-round latency term while sending identical bytes (§10)."
    );
}
