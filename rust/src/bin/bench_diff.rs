//! CI perf gate: compare this run's `BENCH_<suite>.json` trajectory files
//! against the committed baselines and fail on median regressions.
//!
//! ```text
//! bench_diff --baseline ../benchmarks/baselines --current .. \
//!            [--threshold 25] [--summary $GITHUB_STEP_SUMMARY] [--suites a,b]
//! ```
//!
//! * `--current` — directory holding the just-produced `BENCH_*.json`
//!   files (the repo root in the bench-smoke job).
//! * `--baseline` — directory of committed baselines with the same file
//!   names. A missing file, or one flagged `"bootstrap": true`, is
//!   reported but never gates — that's the bootstrap path until a real
//!   bench-smoke artifact is committed (see ROADMAP "Perf trajectory").
//! * `--threshold` — gate threshold in percent (default 25: a suite row
//!   fails when its median exceeds baseline × 1.25).
//! * `--summary` — file to *append* the markdown report to; defaults to
//!   `$GITHUB_STEP_SUMMARY` when set. The report includes per-suite
//!   verdict tables plus the lane-vs-bitsliced and triples-PRG ratio
//!   tables when the ablation suite carries them.
//!
//! Exit codes: 0 ok / informational, 1 regression detected, 2 usage or
//! I/O error. The comparison logic itself lives in
//! `hummingbird::util::benchkit` and is unit-tested there.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use hummingbird::util::benchkit::{diff_suite, markdown_layout_table, markdown_suite_table};
use hummingbird::util::cli::Args;
use hummingbird::util::json::{self, Json};

fn main() {
    std::process::exit(run());
}

fn run() -> i32 {
    let args = Args::from_env();
    let current_dir = PathBuf::from(args.opt("current").unwrap_or("."));
    let baseline_dir = PathBuf::from(args.opt("baseline").unwrap_or("benchmarks/baselines"));
    let threshold_pct: f64 = match args.opt("threshold").unwrap_or("25").parse() {
        Ok(t) => t,
        Err(_) => {
            eprintln!("bench_diff: --threshold must be a number (percent)");
            return 2;
        }
    };
    let threshold = threshold_pct / 100.0;
    let only: Option<Vec<String>> =
        args.opt("suites").map(|s| s.split(',').map(|x| x.trim().to_string()).collect());

    let mut files = match bench_files(&current_dir) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("bench_diff: scanning {}: {e}", current_dir.display());
            return 2;
        }
    };
    files.sort();
    if let Some(only) = &only {
        files.retain(|(suite, _)| only.iter().any(|o| o == suite));
    }
    if files.is_empty() {
        eprintln!(
            "bench_diff: no BENCH_*.json files under {} — did the bench suites run?",
            current_dir.display()
        );
        return 2;
    }

    let mut summary = String::from("## Bench perf gate\n\n");
    let mut regressed = 0usize;
    let mut gated = 0usize;
    for (suite, path) in &files {
        let current = match json::parse_file(path) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("bench_diff: {e}");
                return 2;
            }
        };
        let base_path = baseline_dir.join(format!("BENCH_{suite}.json"));
        let baseline: Option<Json> = if base_path.is_file() {
            match json::parse_file(&base_path) {
                Ok(j) => Some(j),
                Err(e) => {
                    eprintln!("bench_diff: {e}");
                    return 2;
                }
            }
        } else {
            None
        };
        let diff = diff_suite(suite, baseline.as_ref(), &current);
        if !diff.bootstrap {
            gated += 1;
        }
        let regs = diff.regressions(threshold);
        for r in &regs {
            eprintln!(
                "REGRESSION {suite}/{}: {:.3e}s -> {:.3e}s ({:.2}x > {:.2}x allowed)",
                r.name,
                r.baseline_median_s,
                r.current_median_s,
                r.ratio(),
                1.0 + threshold
            );
        }
        regressed += regs.len();
        summary.push_str(&markdown_suite_table(&diff, threshold));
        if let Some(t) = markdown_layout_table(&current) {
            summary.push_str(&t);
        }
    }
    summary.push_str(&format!(
        "\n{} suite(s) compared, {} gated, {} regression(s) at +{threshold_pct}% threshold.\n",
        files.len(),
        gated,
        regressed
    ));
    print!("{summary}");

    let summary_path = args
        .opt("summary")
        .map(PathBuf::from)
        .or_else(|| std::env::var_os("GITHUB_STEP_SUMMARY").map(PathBuf::from));
    if let Some(p) = summary_path {
        // Append: GitHub concatenates step-summary writes, and local users
        // may aggregate multiple invocations into one file.
        let r = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&p)
            .and_then(|mut f| f.write_all(summary.as_bytes()));
        if let Err(e) = r {
            eprintln!("bench_diff: writing summary {}: {e}", p.display());
            return 2;
        }
    }

    if regressed > 0 {
        1
    } else {
        0
    }
}

/// `(suite, path)` for every `BENCH_<suite>.json` directly under `dir`.
fn bench_files(dir: &Path) -> std::io::Result<Vec<(String, PathBuf)>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let suite = match name.strip_prefix("BENCH_").and_then(|n| n.strip_suffix(".json")) {
            Some(s) => s.to_string(),
            None => continue,
        };
        out.push((suite, path));
    }
    Ok(out)
}
