"""Tensor-archive I/O shared with the Rust side.

Format: `<prefix>.json` manifest + `<prefix>.bin` raw little-endian data.

    {"tensors": [{"name": "w3", "shape": [8,3,3,3],
                  "dtype": "f32"|"i32", "offset": 0, "count": 216}, ...]}

Rust reader: `rust/src/model/weights.rs`.
"""

import json
import os

import numpy as np

DTYPES = {"f32": np.float32, "i32": np.int32}


def save_tensors(prefix: str, tensors: dict) -> None:
    """tensors: name -> np.ndarray (float32 or int32)."""
    os.makedirs(os.path.dirname(prefix), exist_ok=True)
    manifest = {"tensors": []}
    offset = 0
    with open(prefix + ".bin", "wb") as f:
        for name, arr in tensors.items():
            arr = np.asarray(arr)
            if arr.dtype == np.float64:
                arr = arr.astype(np.float32)
            if arr.dtype == np.int64:
                arr = arr.astype(np.int32)
            dtype = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32"}[arr.dtype]
            data = np.ascontiguousarray(arr).tobytes()
            f.write(data)
            manifest["tensors"].append({
                "name": name,
                "shape": list(arr.shape),
                "dtype": dtype,
                "offset": offset,
                "count": int(arr.size),
            })
            offset += len(data)
    with open(prefix + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_tensors(prefix: str) -> dict:
    with open(prefix + ".json") as f:
        manifest = json.load(f)
    out = {}
    raw = open(prefix + ".bin", "rb").read()
    for t in manifest["tensors"]:
        np_dtype = DTYPES[t["dtype"]]
        count = t["count"]
        arr = np.frombuffer(raw, dtype=np_dtype,
                            count=count, offset=t["offset"])
        out[t["name"]] = arr.reshape(t["shape"]).copy()
    return out
