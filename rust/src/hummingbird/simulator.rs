//! The lightweight MPC simulator (paper §4.1.1).
//!
//! "The simulator simply performs a single-node ML inference for all layers
//! except ReLU. Only for ReLU layers, the simulator simulates what
//! HummingBird would do during a real MPC-based inference, i.e., converts
//! the floating point values into an integer ring element, generates secret
//! shares, discards bits, and calculates DReLU."
//!
//! Our DReLU decision here is **bit-exact** to the Rust GMW engine's
//! two-party protocol (same window math on the same ring), so simulator
//! accuracy equals online accuracy up to fixed-point truncation noise —
//! property-tested in `rust/tests/mpc_vs_plain.rs`.

use crate::crypto::prg::Prg;
use crate::gmw::ReluPlan;
use crate::hummingbird::PlanSet;
use crate::model::plain::PlainExecutor;
use crate::ring::{self, FixedPoint};

/// Simulate the reduced-ring DReLU decision for one plaintext value.
///
/// Returns true if the (simulated two-party) protocol would keep the value.
#[inline]
pub fn sim_drelu_keep(x: f64, plan: ReluPlan, fx: FixedPoint, prg: &mut Prg) -> bool {
    let w = plan.width();
    debug_assert!(w >= 1);
    let xi = fx.encode(x);
    let r = prg.next_u64();
    let a0 = ring::bit_window(r, plan.k, plan.m);
    let a1 = ring::bit_window(xi.wrapping_sub(r), plan.k, plan.m);
    let t = a0.wrapping_add(a1) & ring::low_mask(w);
    ring::msb_w(t, w) == 0
}

/// Apply the simulated approximate ReLU in place.
pub fn sim_relu_inplace(v: &mut [f32], plan: ReluPlan, fx: FixedPoint, prg: &mut Prg) {
    if plan.is_identity() {
        return;
    }
    if plan.is_baseline() {
        for e in v.iter_mut() {
            if *e < 0.0 {
                *e = 0.0;
            }
        }
        return;
    }
    for e in v.iter_mut() {
        if !sim_drelu_keep(*e as f64, plan, fx, prg) {
            *e = 0.0;
        }
    }
}

/// Deterministic per-(seed, batch, node) PRG so a ReLU node's mask
/// randomness does not depend on evaluation order or checkpointing.
pub fn node_prg(seed: u64, batch_lo: usize, node: usize) -> Prg {
    Prg::new(seed ^ ((batch_lo as u64) << 24) ^ node as u64, sim_stream())
}

/// Build the simulator's ReLU hook for one batch.
pub fn plan_hook<'a>(
    plans: &'a PlanSet,
    fx: FixedPoint,
    seed: u64,
    batch_lo: usize,
) -> impl FnMut(usize, usize, &mut [f32]) + 'a {
    move |node: usize, group: usize, v: &mut [f32]| {
        let plan = plans.plan_for(group);
        if plan.is_baseline() || plan.is_identity() {
            sim_relu_inplace(v, plan, fx, &mut Prg::new(0, 0));
        } else {
            let mut prg = node_prg(seed, batch_lo, node);
            sim_relu_inplace(v, plan, fx, &mut prg);
        }
    }
}

/// Count argmax hits against labels.
pub fn count_correct(logits: &[f32], labels: &[i32], classes: usize) -> usize {
    PlainExecutor::argmax(logits, classes)
        .iter()
        .zip(labels)
        .filter(|(p, l)| **p == **l as usize)
        .count()
}

/// Evaluate classification accuracy of `exec` under `plans` on all samples
/// given, batched. Deterministic given `seed`.
pub fn evaluate_plans(
    exec: &PlainExecutor,
    images: &[f32],
    labels: &[i32],
    sample_elems: usize,
    batch: usize,
    plans: &PlanSet,
    seed: u64,
) -> crate::error::Result<f64> {
    let fx = FixedPoint::new(exec.cfg.frac_bits);
    let classes = exec.cfg.num_classes;
    let n = labels.len();
    let mut correct = 0usize;
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + batch).min(n);
        let b = hi - lo;
        let x = &images[lo * sample_elems..hi * sample_elems];
        let mut hook = plan_hook(plans, fx, seed, lo);
        let logits = exec.forward_with(x, b, &mut hook)?;
        correct += count_correct(&logits, &labels[lo..hi], classes);
        lo = hi;
    }
    Ok(correct as f64 / n as f64)
}

/// PRG stream id for simulator randomness (arbitrary, domain-separated).
#[inline]
const fn sim_stream() -> u64 {
    0x51b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_plan_is_exact_relu() {
        let fx = FixedPoint::new(12);
        let mut prg = Prg::new(1, 1);
        let mut v = vec![-1.5f32, 0.0, 2.25, -0.001];
        sim_relu_inplace(&mut v, ReluPlan::BASELINE, fx, &mut prg);
        assert_eq!(v, vec![0.0, 0.0, 2.25, 0.0]);
    }

    /// Theorem 1: with k covering the value range and m = 0, the simulated
    /// decision equals exact DReLU for every value.
    #[test]
    fn eco_window_is_exact() {
        let fx = FixedPoint::new(12);
        let plan = ReluPlan::new(20, 0).unwrap(); // covers |x| < 2^7 at f=12
        let mut prg = Prg::new(2, 2);
        for i in -1000..1000 {
            let x = i as f64 * 0.05;
            if x.abs() >= 127.0 {
                continue;
            }
            let keep = sim_drelu_keep(x, plan, fx, &mut prg);
            assert_eq!(keep, x >= 0.0 || fx.encode(x) == 0, "x={x}");
        }
    }

    /// Theorem 2: m > 0 prunes small positives probabilistically, never
    /// large ones, and always drops negatives (within range).
    #[test]
    fn low_bit_drop_prunes_small_positives() {
        let fx = FixedPoint::new(12);
        let plan = ReluPlan::new(20, 8).unwrap(); // threshold 2^8/2^12 = 1/16
        let mut prg = Prg::new(3, 3);
        let thresh = 2f64.powi(8 - 12);
        let mut small_kept = 0;
        let mut small_total = 0;
        for i in 0..5000 {
            let x = (i % 100) as f64 * 0.002 + 0.0001; // (0, 0.2)
            let keep = sim_drelu_keep(x, plan, fx, &mut prg);
            if x >= thresh {
                assert!(keep, "large positive pruned: {x}");
            } else {
                small_total += 1;
                small_kept += keep as usize;
            }
            assert!(!sim_drelu_keep(-x, plan, fx, &mut prg) || fx.encode(-x) == 0);
        }
        assert!(small_kept > 0 && small_kept < small_total, "{small_kept}/{small_total}");
    }

}
