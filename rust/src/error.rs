//! Error types for the HummingBird library.
//!
//! The library uses a single [`Error`] enum so that protocol, I/O, config and
//! runtime failures compose across module boundaries without boxing. Binaries
//! and examples convert into `anyhow::Error` at the edge. `Display` and
//! `std::error::Error` are implemented by hand so the offline build carries
//! no proc-macro dependency (`thiserror` is not in the vendored crate set).

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Library-wide error type.
#[derive(Debug)]
pub enum Error {
    /// Malformed or inconsistent configuration.
    Config(String),

    /// JSON parse / serialize failure (our hand-rolled parser).
    Json { offset: usize, msg: String },

    /// Secret-sharing / protocol invariant violation.
    Protocol(String),

    /// Transport-level failure (channel closed, socket error, framing).
    Transport(String),

    /// A deadline expired: a peer did not produce (or accept) a round's
    /// bytes within `NetConfig::round_timeout`, or a handshake/dial ran
    /// past its budget. Deliberately **fatal** (see DESIGN.md §7): a
    /// hung-but-connected peer is indistinguishable from an arbitrarily
    /// slow one, and reconnecting cannot conjure the missing bytes — the
    /// session fails the in-flight job instead of wedging the process.
    Timeout(String),

    /// The serving layer refused the request because it is at capacity
    /// (bounded admission queue full) or deliberately degraded
    /// (crash-loop breaker open, drain in progress) — see DESIGN.md §9.
    /// Unlike the fatal transport errors, this is **retryable by the
    /// client**: nothing about the request was wrong, the service just
    /// could not take it *now* ([`Error::client_should_retry`]).
    Overloaded(String),

    /// A per-request deadline (`--request-timeout-ms`) expired before
    /// the service produced an answer: the request was shed from the
    /// queue, or the caller stopped waiting (DESIGN.md §9). Distinct
    /// from [`Error::Timeout`], which is a *session-layer* round
    /// deadline and fatal for the whole party session.
    Deadline(String),

    /// The service is not running: it was never started, is past its
    /// drain deadline, or has stopped. Distinct from
    /// [`Error::Transport`] — callers can tell "service stopping" from
    /// a real transport fault (DESIGN.md §9).
    Unavailable(String),

    /// Wire-format violation: a payload whose length or framing does not
    /// match what the protocol step expects (truncated or corrupt data
    /// must never be silently zero-padded into "valid" shares).
    Wire(String),

    /// Beaver-triple store exhausted or mismatched.
    Beaver(String),

    /// Shape mismatch in tensor ops or model graph wiring.
    Shape(String),

    /// Model graph / weights problem.
    Model(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Search engine failure (budget infeasible, no candidates, ...).
    Search(String),

    /// Kernel dispatch failure: a forced kernel arm (`--kernel simd` /
    /// `HB_KERNEL=simd`) is unavailable on this CPU, or the boot-time
    /// selfcheck found the dispatched arm diverging from the scalar
    /// reference (DESIGN.md §11). Fatal: secret-share kernels must be
    /// bit-identical across arms, so serving with a diverging kernel is
    /// never acceptable.
    Kernel(String),

    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json { offset, msg } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Timeout(m) => write!(f, "timeout: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::Deadline(m) => write!(f, "request deadline expired: {m}"),
            Error::Unavailable(m) => write!(f, "service unavailable: {m}"),
            Error::Wire(m) => write!(f, "wire format error: {m}"),
            Error::Beaver(m) => write!(f, "beaver error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Search(m) => write!(f, "search error: {m}"),
            Error::Kernel(m) => write!(f, "kernel dispatch error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used pervasively in the protocol code.
    pub fn protocol(msg: impl fmt::Display) -> Self {
        Error::Protocol(msg.to_string())
    }
    /// Shorthand constructor for configuration errors.
    pub fn config(msg: impl fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }
    /// Shorthand constructor for shape errors.
    pub fn shape(msg: impl fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }
    /// Shorthand constructor for wire-format errors.
    pub fn wire(msg: impl fmt::Display) -> Self {
        Error::Wire(msg.to_string())
    }
    /// Shorthand constructor for runtime errors.
    pub fn runtime(msg: impl fmt::Display) -> Self {
        Error::Runtime(msg.to_string())
    }
    /// Shorthand constructor for deadline-expired errors.
    pub fn timeout(msg: impl fmt::Display) -> Self {
        Error::Timeout(msg.to_string())
    }
    /// Shorthand constructor for admission-refused errors.
    pub fn overloaded(msg: impl fmt::Display) -> Self {
        Error::Overloaded(msg.to_string())
    }
    /// Shorthand constructor for per-request deadline expiries.
    pub fn deadline(msg: impl fmt::Display) -> Self {
        Error::Deadline(msg.to_string())
    }
    /// Shorthand constructor for service-not-running errors.
    pub fn unavailable(msg: impl fmt::Display) -> Self {
        Error::Unavailable(msg.to_string())
    }
    /// Shorthand constructor for kernel-dispatch errors.
    pub fn kernel(msg: impl fmt::Display) -> Self {
        Error::Kernel(msg.to_string())
    }

    /// Client-side retry classification for the serving layer
    /// (DESIGN.md §9): `true` exactly for [`Error::Overloaded`] — the
    /// request itself was fine, the service just refused it *now*
    /// (queue full, breaker open, drain in progress), so resubmitting
    /// after a backoff can succeed. Everything else either failed the
    /// request on its merits or means the service is going away.
    pub fn client_should_retry(&self) -> bool {
        matches!(self, Error::Overloaded(_))
    }

    /// Retryable/fatal classification for the session layer (DESIGN.md §7).
    ///
    /// **Retryable** means "the link died but the peer may still be alive":
    /// the TCP session layer answers with a reconnect + resync-and-resend
    /// pass, and because every round is a deterministic function of the
    /// parties' shares, recovery is bit-identical to a fault-free run.
    /// Only connection-level I/O faults qualify. Everything else — wire
    /// corruption ([`Error::Wire`]), protocol divergence, deadline expiry
    /// ([`Error::Timeout`]), dealer-stream divergence ([`Error::Beaver`])
    /// — is **fatal** for the in-flight job: retrying cannot repair state
    /// that was never produced or has already diverged.
    pub fn is_retryable(&self) -> bool {
        use std::io::ErrorKind;
        match self {
            Error::Io(e) => matches!(
                e.kind(),
                ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::BrokenPipe
                    | ErrorKind::UnexpectedEof
                    | ErrorKind::NotConnected
                    | ErrorKind::WriteZero
            ),
            _ => false,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("xla: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The retryable set is exactly the connection-level I/O faults; wire
    /// corruption, deadlines and protocol divergence stay fatal.
    #[test]
    fn retryable_classification() {
        use std::io::ErrorKind;
        for kind in [
            ErrorKind::ConnectionReset,
            ErrorKind::ConnectionAborted,
            ErrorKind::BrokenPipe,
            ErrorKind::UnexpectedEof,
        ] {
            assert!(Error::Io(std::io::Error::new(kind, "x")).is_retryable(), "{kind:?}");
        }
        for fatal in [
            Error::Io(std::io::Error::new(std::io::ErrorKind::TimedOut, "x")),
            Error::Io(std::io::Error::new(std::io::ErrorKind::WouldBlock, "x")),
            Error::timeout("round deadline"),
            Error::wire("ragged payload"),
            Error::protocol("divergence"),
            Error::Beaver("schedule mismatch".into()),
            Error::Transport("out-of-order frame".into()),
            Error::overloaded("queue full"),
            Error::deadline("request expired in queue"),
            Error::unavailable("service stopped"),
            Error::kernel("forced simd unavailable"),
        ] {
            assert!(!fatal.is_retryable(), "{fatal}");
        }
    }

    /// `client_should_retry` marks exactly the admission refusals: a
    /// shed request or a stopping service must not invite a resubmit.
    #[test]
    fn client_retry_classification() {
        assert!(Error::overloaded("queue full").client_should_retry());
        assert!(Error::overloaded("degraded").client_should_retry());
        for no in [
            Error::deadline("expired in queue"),
            Error::unavailable("draining"),
            Error::timeout("round deadline"),
            Error::wire("ragged"),
            Error::Transport("link".into()),
        ] {
            assert!(!no.client_should_retry(), "{no}");
        }
    }
}
