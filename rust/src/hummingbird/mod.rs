//! HummingBird plan management (paper §4): per-ReLU-group (k, m) windows,
//! JSON I/O for searched plans, and budget accounting.
//!
//! Submodules: [`simulator`] (the lightweight MPC simulator of §4.1.1) and
//! [`search`] (HummingBird-eco and HummingBird-*b*, §4.1.2).

pub mod search;
pub mod simulator;

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::gmw::ReluPlan;
use crate::model::graph::ModelConfig;
use crate::util::json::{self, Json};

/// A full model plan: one [`ReluPlan`] per ReLU group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanSet {
    /// plans[group] = (k, m) window for every ReLU in that group.
    pub groups: BTreeMap<usize, ReluPlan>,
    /// Free-form provenance (search strategy, budget, accuracy).
    pub meta: BTreeMap<String, String>,
}

impl PlanSet {
    /// The exact CrypTen-equivalent baseline for `n_groups` groups.
    pub fn baseline(n_groups: usize) -> PlanSet {
        PlanSet {
            groups: (0..n_groups).map(|g| (g, ReluPlan::BASELINE)).collect(),
            meta: BTreeMap::new(),
        }
    }

    /// Uniform plan: same window for every group (the naive strategy the
    /// paper's Fig 12 compares against).
    pub fn uniform(n_groups: usize, k: u32, m: u32) -> Result<PlanSet> {
        let plan = ReluPlan::new(k, m)?;
        Ok(PlanSet {
            groups: (0..n_groups).map(|g| (g, plan)).collect(),
            meta: BTreeMap::new(),
        })
    }

    pub fn plan_for(&self, group: usize) -> ReluPlan {
        self.groups.get(&group).copied().unwrap_or(ReluPlan::BASELINE)
    }

    pub fn set(&mut self, group: usize, plan: ReluPlan) {
        self.groups.insert(group, plan);
    }

    /// Total DReLU bits this plan spends on one sample of `cfg`, and the
    /// baseline's total — the paper's budget metric (§4.1.2: "the total
    /// number of bits used in each DReLU computation combined must be
    /// 1/16 or less of the original number of bits combined").
    pub fn budget_bits(&self, cfg: &ModelConfig) -> (u64, u64) {
        let mut used = 0u64;
        let mut baseline = 0u64;
        for (_, group, elems) in cfg.relu_elems() {
            let plan = self.plan_for(group);
            used += plan.width() as u64 * elems as u64;
            baseline += 64u64 * elems as u64;
        }
        (used, baseline)
    }

    /// used/baseline bit fraction.
    pub fn budget_fraction(&self, cfg: &ModelConfig) -> f64 {
        let (u, b) = self.budget_bits(cfg);
        u as f64 / b as f64
    }

    // ------------------------------------------------------------------
    // JSON round-trip (shared with python train.py --finetune).
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let groups = Json::Obj(
            self.groups
                .iter()
                .map(|(g, p)| {
                    (
                        g.to_string(),
                        Json::obj(vec![
                            ("k", Json::Int(p.k as i64)),
                            ("m", Json::Int(p.m as i64)),
                        ]),
                    )
                })
                .collect(),
        );
        let meta = Json::Obj(
            self.meta.iter().map(|(k, v)| (k.clone(), Json::str(v.clone()))).collect(),
        );
        Json::obj(vec![("groups", groups), ("meta", meta)])
    }

    pub fn from_json(j: &Json) -> Result<PlanSet> {
        let mut groups = BTreeMap::new();
        for (g, p) in j.get("groups")?.as_obj()? {
            let g: usize =
                g.parse().map_err(|_| Error::config(format!("bad group id {g}")))?;
            groups.insert(
                g,
                ReluPlan::new(p.get_usize("k")? as u32, p.get_usize("m")? as u32)?,
            );
        }
        let mut meta = BTreeMap::new();
        if let Some(m) = j.opt("meta") {
            for (k, v) in m.as_obj()? {
                meta.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
            }
        }
        Ok(PlanSet { groups, meta })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<PlanSet> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// One-line human-readable summary, e.g. `g0=[2,18) g1=[0,14) ...`.
    pub fn summary(&self) -> String {
        self.groups
            .iter()
            .map(|(g, p)| format!("g{g}=[{},{})", p.m, p.k))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut ps = PlanSet::baseline(3);
        ps.set(1, ReluPlan::new(18, 4).unwrap());
        ps.meta.insert("strategy".into(), "eco".into());
        let back = PlanSet::from_json(&ps.to_json()).unwrap();
        assert_eq!(ps, back);
    }

    #[test]
    fn uniform_and_summary() {
        let ps = PlanSet::uniform(2, 8, 2).unwrap();
        assert_eq!(ps.plan_for(0).width(), 6);
        assert_eq!(ps.plan_for(5), ReluPlan::BASELINE); // unknown group
        assert!(ps.summary().contains("g1=[2,8)"));
    }
}
