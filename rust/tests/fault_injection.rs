//! Chaos suite for the fault-tolerant session layer (DESIGN.md §7).
//!
//! Three acceptance scenarios:
//!
//! 1. A hung peer past `round_timeout` fails its job with a per-job error
//!    — the coordinator process is not wedged and serves the next request.
//! 2. A seeded drop-at-round-k over real TCP recovers via
//!    reconnect-and-resend with bit-identical outputs AND bit-identical
//!    protocol byte accounting, across both binary layouts and with the
//!    offline prefetcher on or off.
//! 3. After an injected party crash, the coordinator answers the failed
//!    job with an error, respawns the party session, serves the next
//!    request, and the metrics counters pin exactly one failed job and
//!    one session restart.
//!
//! The TCP scenarios are self-contained (loopback, ephemeral ports). The
//! coordinator scenarios need the micronet artifacts and skip otherwise
//! (same gating as tests/coordinator_serve.rs).

use std::time::Duration;

use hummingbird::beaver::schedule::TripleSchedule;
use hummingbird::coordinator::{ClockHandle, Coordinator, LifecycleState, ServeOptions};
use hummingbird::crypto::prg::Prg;
use hummingbird::error::Error;
use hummingbird::gmw::kernels::{BitslicedKernels, KernelBackend, RustKernels};
use hummingbird::gmw::{GmwParty, ReluPlan};
use hummingbird::hummingbird::PlanSet;
use hummingbird::model::{Dataset, ModelConfig};
use hummingbird::net::accounting::Phase;
use hummingbird::net::fault::{FaultKind, FaultProfile, FaultyTransport};
use hummingbird::net::tcp::{BoundListener, TcpTransport};
use hummingbird::net::{NetConfig, RecvBufs, Transport};
use hummingbird::sharing::{reconstruct_arith, share_arith};

const MODEL: &str = "micronet_synth10";

fn ready() -> Option<std::path::PathBuf> {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf();
    if repo.join("artifacts/manifest.json").exists()
        && repo.join(format!("artifacts/weights/{MODEL}.json")).exists()
    {
        Some(repo)
    } else {
        eprintln!("skipping: artifacts/weights missing");
        None
    }
}

/// Loopback 2-party TCP mesh on ephemeral ports: party 0 binds port 0 and
/// party 1 (highest rank) only dials, so its own listen address is never
/// used.
fn tcp_pair(session: u64, cfg: NetConfig) -> (TcpTransport, TcpTransport) {
    let l0 = BoundListener::bind(0, "127.0.0.1:0").unwrap();
    let addrs = vec![l0.local_addr().unwrap().to_string(), "127.0.0.1:0".to_string()];
    let a0 = addrs.clone();
    let h0 = std::thread::spawn(move || l0.establish(&a0, session, cfg).unwrap());
    let t1 = TcpTransport::connect_with(1, &addrs, session, cfg).unwrap();
    (h0.join().unwrap(), t1)
}

/// What one ReLU-over-TCP run produced: per-party output shares, the
/// protocol byte/round accounting, and how many link recoveries happened.
struct RunOut {
    outputs: Vec<Vec<u64>>,
    bytes: u64,
    rounds: u64,
    reconnects: u64,
    resends: u64,
}

fn drive_party<T: Transport + 'static, K: KernelBackend>(
    mut party: GmwParty<T, K>,
    shares: &[u64],
    plan: ReluPlan,
    prefetch: bool,
) -> (Vec<u64>, u64, u64) {
    if prefetch {
        let schedule = TripleSchedule::for_relu(shares.len(), plan, party.parties());
        party.enable_prefetch(schedule, false);
    }
    let out = party.relu(shares, plan).unwrap();
    let trace = party.transport.trace();
    (out, trace.total_bytes(), trace.total_rounds())
}

/// Run a 2-party ReLU over real TCP, optionally with an injected fault
/// profile (wrapped around both endpoints; only the profile's party arms).
fn run_relu_pair(
    shares: &[Vec<u64>],
    plan: ReluPlan,
    bitsliced: bool,
    prefetch: bool,
    fault: Option<FaultProfile>,
) -> RunOut {
    let (t0, t1) = tcp_pair(0xfa17, NetConfig::default());
    let stats = [t0.net_stats(), t1.net_stats()];
    let mut handles = Vec::new();
    for (me, t) in [t0, t1].into_iter().enumerate() {
        let my_shares = shares[me].clone();
        let fault = fault.clone();
        handles.push(std::thread::spawn(move || match (fault, bitsliced) {
            (Some(p), true) => drive_party(
                GmwParty::with_kernels(FaultyTransport::new(t, &p), 7, BitslicedKernels::default()),
                &my_shares,
                plan,
                prefetch,
            ),
            (Some(p), false) => drive_party(
                GmwParty::with_kernels(FaultyTransport::new(t, &p), 7, RustKernels::default()),
                &my_shares,
                plan,
                prefetch,
            ),
            (None, true) => drive_party(
                GmwParty::with_kernels(t, 7, BitslicedKernels::default()),
                &my_shares,
                plan,
                prefetch,
            ),
            (None, false) => drive_party(
                GmwParty::with_kernels(t, 7, RustKernels::default()),
                &my_shares,
                plan,
                prefetch,
            ),
        }));
    }
    let done: Vec<(Vec<u64>, u64, u64)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Party 0 and party 1 must see symmetric protocol accounting.
    assert_eq!((done[0].1, done[0].2), (done[1].1, done[1].2), "asymmetric accounting");
    let (bytes, rounds) = (done[0].1, done[0].2);
    let outputs: Vec<Vec<u64>> = done.into_iter().map(|(out, _, _)| out).collect();
    let (mut reconnects, mut resends) = (0, 0);
    for s in &stats {
        let snap = s.snapshot();
        reconnects += snap.reconnects;
        resends += snap.resends;
    }
    RunOut { outputs, bytes, rounds, reconnects, resends }
}

/// Acceptance scenario 2: a seeded link drop at round k over real TCP is
/// healed by the reconnect-and-resend path with bit-identical per-party
/// outputs and bit-identical protocol byte/round accounting — across both
/// binary layouts and with the offline prefetcher on or off.
#[test]
fn drop_at_round_k_recovers_bit_identical() {
    let n = 256;
    // Exact full-width plan: the plaintext ReLU reference below holds for
    // arbitrary inputs (a reduced window would approximate).
    let plan = ReluPlan::BASELINE;
    let mut prg = Prg::new(0xd10f, 0);
    let x: Vec<u64> = (0..n)
        .map(|i| if i % 3 == 0 { prg.next_u64() | (1u64 << 63) } else { prg.next_u64() >> 1 })
        .collect();
    let shares = share_arith(&mut prg, &x, 2);

    // Fault-free reference (lane layout, synchronous dealer).
    let reference = run_relu_pair(&shares, plan, false, false, None);
    assert_eq!(reference.reconnects, 0);
    let expect: Vec<u64> = x.iter().map(|v| if (*v as i64) < 0 { 0 } else { *v }).collect();
    assert_eq!(reconstruct_arith(&reference.outputs), expect, "reference ReLU wrong");

    // Party 1 severs its link to party 0 right before round 2, in every
    // layout/prefetch combination. Recovery must be invisible in both the
    // outputs and the protocol accounting.
    let profile = FaultProfile::single(1, 2, FaultKind::Drop);
    for (bitsliced, prefetch) in [(false, false), (false, true), (true, false), (true, true)] {
        let run = run_relu_pair(&shares, plan, bitsliced, prefetch, Some(profile.clone()));
        assert_eq!(
            run.outputs, reference.outputs,
            "recovered run diverged (bitsliced={bitsliced}, prefetch={prefetch})"
        );
        assert_eq!(
            (run.bytes, run.rounds),
            (reference.bytes, reference.rounds),
            "recovery leaked into protocol accounting (bitsliced={bitsliced}, prefetch={prefetch})"
        );
        assert!(
            run.reconnects >= 2,
            "both endpoints should have recovered the link: {} reconnects",
            run.reconnects
        );
        assert!(run.resends >= 1, "the dropped round's frame should have been resent");
    }
}

/// A RecvBufs sized for the wrong mesh is rejected before any socket IO
/// (satellite coverage: transport error paths over real sockets).
#[test]
fn mismatched_recv_bufs_rejected_over_tcp() {
    let (_t0, mut t1) = tcp_pair(0xbadb, NetConfig::default());
    let mut wrong = RecvBufs::new(3);
    let err = t1.exchange_all_into(Phase::Circuit, b"x", &mut wrong).unwrap_err();
    assert!(!err.is_retryable(), "mesh-size mismatch must be fatal: {err}");
}

/// Acceptance scenario 1: a peer that hangs past `round_timeout` fails the
/// in-flight job with a per-job error — and the coordinator process keeps
/// serving (the very next request succeeds on a respawned session).
#[test]
fn hung_peer_times_out_without_wedging_coordinator() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::baseline(cfg.relu_groups));
    opts.net.round_timeout = Duration::from_millis(100);
    // Party 1 stalls 1.5s before its first exchange: party 0's recv blows
    // the 100ms round deadline long before the sleep ends.
    opts.fault_profile = Some(FaultProfile::single(1, 0, FaultKind::Delay(1500)));
    let svc = Coordinator::start(opts).unwrap();

    let err = svc.infer(dataset.test.batch(0, 1).to_vec()).unwrap_err();
    assert!(err.to_string().contains("inference failed"), "unexpected error: {err}");

    // Not wedged: the respawned session answers.
    let ok = svc.infer(dataset.test.batch(1, 2).to_vec()).unwrap();
    assert_eq!(ok.logits.len(), cfg.num_classes);

    let snap = svc.metrics.snapshot();
    assert_eq!(snap.faults.failed_jobs, 1);
    assert_eq!(snap.faults.timeouts, 1, "root cause should classify as a deadline expiry");
    assert_eq!(snap.faults.sessions_restarted, 1);
    svc.shutdown();
}

/// Acceptance scenario 3: an injected party crash fails exactly one job,
/// the coordinator respawns the session and serves the next request, and
/// the metrics counters match exactly.
#[test]
fn party_crash_fails_one_job_then_serves_again() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::baseline(cfg.relu_groups));
    opts.fault_profile = Some(FaultProfile::single(1, 0, FaultKind::Crash));
    let svc = Coordinator::start(opts).unwrap();

    svc.infer(dataset.test.batch(0, 1).to_vec()).unwrap_err();
    let ok = svc.infer(dataset.test.batch(1, 2).to_vec()).unwrap();
    assert_eq!(ok.logits.len(), cfg.num_classes);

    let snap = svc.metrics.snapshot();
    assert_eq!(snap.faults.failed_jobs, 1, "exactly one failed job");
    assert_eq!(snap.faults.timeouts, 0, "a crash is not a deadline expiry");
    assert_eq!(snap.faults.sessions_restarted, 1, "exactly one respawn");
    assert_eq!(snap.batches_done, 1, "only the successful batch counts");
    svc.shutdown();
}

/// Poll (real time) until the coordinator reaches `want` — the batcher
/// notices mock-clock advances within a scheduling quantum.
fn wait_for_state(svc: &Coordinator, want: LifecycleState) {
    let t0 = std::time::Instant::now();
    while svc.metrics.state() != want {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "timed out waiting for {want}, still {}",
            svc.metrics.state()
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Crash-loop breaker (DESIGN.md §9), with all breaker timing pinned by
/// an injected mock clock — no wall-clock sleeps decide the outcome, so
/// the scenario is deterministic under parallel test threads:
/// `max_restarts` consecutive boot failures trip the coordinator into
/// `Degraded` (admission answers `Overloaded` immediately), background
/// probes retry on capped backoff as the test advances the clock, and
/// the first successful boot returns the service to `Serving`.
#[test]
fn crash_loop_trips_breaker_then_recovers() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::baseline(cfg.relu_groups));
    opts.max_restarts = 3;
    // 3 boot failures trip the breaker; 2 more fail the first probes; the
    // probe after that boots for real.
    opts.fault_profile = Some(FaultProfile::boot_failures(5));
    let (clock, mock) = ClockHandle::mock();
    opts.clock = clock;
    let svc = Coordinator::start(opts).unwrap();

    // Backoffs run on the mock clock (sleep = yield), so the batcher
    // burns through its restart budget without any wall-clock wait.
    wait_for_state(&svc, LifecycleState::Degraded);
    let err = svc.infer(dataset.test.batch(0, 1).to_vec()).unwrap_err();
    assert!(matches!(err, Error::Overloaded(_)), "degraded must answer Overloaded: {err}");
    assert!(err.client_should_retry());

    // Probes fire only as the test moves time past their capped backoff;
    // once the bootfail budget is spent, the next probe boots and closes
    // the breaker.
    let t0 = std::time::Instant::now();
    while svc.metrics.state() != LifecycleState::Serving {
        assert!(t0.elapsed() < Duration::from_secs(30), "probe never recovered");
        mock.advance(Duration::from_millis(500));
        std::thread::sleep(Duration::from_millis(5));
    }
    let ok = svc.infer(dataset.test.batch(0, 1).to_vec()).unwrap();
    assert_eq!(ok.logits.len(), cfg.num_classes);

    let snap = svc.metrics.snapshot();
    assert!(snap.admission.rejected_degraded >= 1, "the degraded refusal must be counted");
    assert_eq!(snap.faults.sessions_restarted, 1, "only the probe boot counts as a restart");
    let fin = svc.shutdown_with_deadline(Duration::from_secs(30));
    assert_eq!(fin.state, LifecycleState::Stopped);
    assert_eq!(fin.live_party_threads, 0);
    assert!(fin.balanced(), "identity must hold: {:?}", fin.admission);
}
