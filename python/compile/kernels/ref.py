"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernels.py`
asserts the Pallas kernels (interpret mode) match these exactly (integer
ops, so equality is bit-exact), and hypothesis sweeps shapes/values.

All ring math is on int64 with two's-complement wraparound — identical bit
patterns to the Rust engine's u64. Right shifts are never used (arithmetic
vs logical ambiguity); the protocol only needs XOR/AND/left-shift/mul/add.
"""

import jax.numpy as jnp

I64 = jnp.int64


def and_open(u, v, a, b):
    """Beaver-AND masked opening: rows [d; e] = [u ^ a; v ^ b]."""
    return jnp.stack([u ^ a, v ^ b], axis=0)


def and_combine(d, e, a, b, c, leader_mask):
    """Beaver-AND combine: z = (leader? d&e) ^ d&b ^ e&a ^ c.

    leader_mask is 0 or -1 (all ones) as an int64 scalar array.
    """
    return ((d & e) & leader_mask) ^ (d & b) ^ (e & a) ^ c


def ks_stage_operands(g, p, s, mask, last: bool):
    """Kogge-Stone stage AND operands.

    mid stage:  u = [p; p], v = [(g << s) & mask; (p << s) & mask]
    last stage: u = [p],    v = [(g << s) & mask]
    `s` and `mask` are int64 scalars (shape ()) so one lowered artifact
    serves every stage of every window width.
    """
    gv = (g << s) & mask
    if last:
        return jnp.stack([p], axis=0), jnp.stack([gv], axis=0)
    pv = (p << s) & mask
    return jnp.stack([p, p], axis=0), jnp.stack([gv, pv], axis=0)


def mult_open(x, y, a, b):
    """Beaver-mult masked opening: rows [d; e] = [x - a; y - b] (mod 2^64)."""
    return jnp.stack([x - a, y - b], axis=0)


def mult_combine(d, e, a, b, c, leader_mask):
    """Beaver-mult combine: z = c + d*b + e*a + (leader? d*e) (mod 2^64)."""
    return c + d * b + e * a + (d * e) * (leader_mask & 1)


def share_matmul(x, w):
    """Ring matmul on shares: (x @ w) mod 2^64, x:[M,K] w:[K,N] int64."""
    return jnp.matmul(x, w, preferred_element_type=I64)
