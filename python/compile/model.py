"""Layer-2: JAX compute graphs for the model zoo (build-time only).

Interprets the shared model-config schema (see archs.py) three ways:

* ``forward_plain``    — f32 forward pass (training, the search engine's
                         plaintext reference, and the plain per-layer HLO
                         artifacts).
* ``share_conv`` etc.  — int64 ring ops on *secret shares* (im2col + the
                         Layer-1 Pallas ``share_matmul``), lowered per layer
                         into the ``share_*`` HLO artifacts the Rust party
                         executes locally.
* ``approx_relu``      — bit-exact simulation of HummingBird's reduced-ring
                         DReLU (uint64 share math identical to the Rust
                         engine), used for finetuning (§4.1.3) with a
                         straight-through gradient.

Python never runs at serving time; everything here exists to be lowered by
aot.py or executed inside train.py.
"""

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from .kernels import matmul as kmm
from .kernels import ref

I64 = jnp.int64
U64 = jnp.uint64


# ---------------------------------------------------------------------------
# Parameter initialization / pytree layout.
# ---------------------------------------------------------------------------

def init_params(cfg, key):
    """He-normal conv/fc parameters keyed by node index: w{i}, b{i}."""
    params = {}
    shapes = node_shapes(cfg)
    for i, node in enumerate(cfg["nodes"]):
        if node["op"] == "conv":
            cin = shapes[node["in"][0]][0]
            k = node["k"]
            key, sub = jax.random.split(key)
            fan_in = cin * k * k
            params[f"w{i}"] = (
                jax.random.normal(sub, (node["out_ch"], cin, k, k), jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
            params[f"b{i}"] = jnp.zeros((node["out_ch"],), jnp.float32)
        elif node["op"] == "fc":
            cin = int(jnp.prod(jnp.array(shapes[node["in"][0]])))
            key, sub = jax.random.split(key)
            params[f"w{i}"] = (
                jax.random.normal(sub, (cin, node["out"]), jnp.float32)
                * jnp.sqrt(2.0 / cin)
            )
            params[f"b{i}"] = jnp.zeros((node["out"],), jnp.float32)
    return params


def node_shapes(cfg):
    """Static (C, H, W) (or (N,) after fc/gap) shape per node."""
    shapes = []
    for node in cfg["nodes"]:
        op = node["op"]
        if op == "input":
            shapes.append(tuple(cfg["input"]))
        elif op == "conv":
            c, h, w = shapes[node["in"][0]]
            s, p, k = node["stride"], node["pad"], node["k"]
            ho = (h + 2 * p - k) // s + 1
            wo = (w + 2 * p - k) // s + 1
            shapes.append((node["out_ch"], ho, wo))
        elif op in ("relu", "add"):
            shapes.append(shapes[node["in"][0]])
        elif op == "gap":
            c, _, _ = shapes[node["in"][0]]
            shapes.append((c,))
        elif op == "fc":
            shapes.append((node["out"],))
        else:
            raise ValueError(f"unknown op {op}")
    return shapes


# ---------------------------------------------------------------------------
# Plain f32 forward.
# ---------------------------------------------------------------------------

def conv_plain(x, w, b, stride, pad):
    """NCHW f32 convolution + bias (one HLO artifact per conv layer)."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def fc_plain(x, w, b):
    return x @ w + b


def forward_plain(cfg, params, x, relu_fn=None):
    """Full f32 forward. `relu_fn(x, group)` defaults to exact ReLU."""
    if relu_fn is None:
        relu_fn = lambda v, g: jnp.maximum(v, 0.0)
    acts = {}
    out = None
    for i, node in enumerate(cfg["nodes"]):
        op = node["op"]
        if op == "input":
            acts[i] = x
        elif op == "conv":
            acts[i] = conv_plain(acts[node["in"][0]], params[f"w{i}"],
                                 params[f"b{i}"], node["stride"], node["pad"])
        elif op == "relu":
            acts[i] = relu_fn(acts[node["in"][0]], node["group"])
        elif op == "add":
            acts[i] = acts[node["in"][0]] + acts[node["in"][1]]
        elif op == "gap":
            acts[i] = jnp.mean(acts[node["in"][0]], axis=(2, 3))
        elif op == "fc":
            v = acts[node["in"][0]].reshape(x.shape[0], -1)
            acts[i] = fc_plain(v, params[f"w{i}"], params[f"b{i}"])
        out = acts[i]
    return out


def pre_relu_activations(cfg, params, x, relu_fn=None):
    """Forward pass that also returns every ReLU node's *input* (used by the
    search engine's range analysis and by tests)."""
    if relu_fn is None:
        relu_fn = lambda v, g: jnp.maximum(v, 0.0)
    acts = {}
    pre = {}
    for i, node in enumerate(cfg["nodes"]):
        op = node["op"]
        if op == "input":
            acts[i] = x
        elif op == "conv":
            acts[i] = conv_plain(acts[node["in"][0]], params[f"w{i}"],
                                 params[f"b{i}"], node["stride"], node["pad"])
        elif op == "relu":
            pre[i] = acts[node["in"][0]]
            acts[i] = relu_fn(pre[i], node["group"])
        elif op == "add":
            acts[i] = acts[node["in"][0]] + acts[node["in"][1]]
        elif op == "gap":
            acts[i] = jnp.mean(acts[node["in"][0]], axis=(2, 3))
        elif op == "fc":
            v = acts[node["in"][0]].reshape(x.shape[0], -1)
            acts[i] = fc_plain(v, params[f"w{i}"], params[f"b{i}"])
    return acts[len(cfg["nodes"]) - 1], pre


# ---------------------------------------------------------------------------
# Share-domain (int64 ring) per-layer graphs.
# ---------------------------------------------------------------------------

def im2col(x, k, stride, pad):
    """[B,C,H,W] -> [B*Ho*Wo, C*k*k] patches, order (c, ky, kx)."""
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    cols = []
    for dy in range(k):
        for dx in range(k):
            sl = xp[:, :, dy:dy + (ho - 1) * stride + 1:stride,
                    dx:dx + (wo - 1) * stride + 1:stride]
            cols.append(sl)  # [B, C, Ho, Wo]
    patches = jnp.stack(cols, axis=2)  # [B, C, k*k, Ho, Wo]
    patches = patches.transpose(0, 3, 4, 1, 2)  # [B, Ho, Wo, C, k*k]
    return patches.reshape(b * ho * wo, c * k * k), (b, ho, wo)


def share_conv(x, wmat, k, stride, pad, out_ch, fast=False):
    """Conv on int64 shares: im2col + ring matmul.

    wmat is the public weight reshaped to [C*k*k, out_ch] and quantized to
    the fixed-point ring; the output scale is 2^(2f) (the Rust party
    truncates and adds the public bias).

    `fast=False` routes through the Layer-1 Pallas kernel (the validated
    TPU-shaped path; under interpret=True it lowers to a grid loop of
    dynamic slices, which XLA-CPU executes slowly). `fast=True` lowers the
    same ring math as a single fused int64 dot — the CPU-deployment hot
    path (see EXPERIMENTS.md §Perf L2). Both variants are emitted by
    aot.py and compared bit-for-bit in tests.
    """
    patches, (b, ho, wo) = im2col(x, k, stride, pad)
    mm = ref.share_matmul if fast else kmm.share_matmul
    y = mm(patches, wmat)  # [B*Ho*Wo, out_ch]
    return y.reshape(b, ho, wo, out_ch).transpose(0, 3, 1, 2)


def share_fc(x, wmat, fast=False):
    """FC on int64 shares: [B, In] @ [In, Out] on the ring."""
    mm = ref.share_matmul if fast else kmm.share_matmul
    return mm(x, wmat)


# ---------------------------------------------------------------------------
# HummingBird approximate-ReLU simulation (bit-exact vs the Rust engine).
# ---------------------------------------------------------------------------

def low_mask(w):
    return jnp.where(
        jnp.uint64(w) >= jnp.uint64(64),
        jnp.uint64(0xFFFFFFFFFFFFFFFF),
        (jnp.uint64(1) << jnp.uint64(w)) - jnp.uint64(1),
    )


def approx_drelu_mask(key, x_f, k, m, frac_bits):
    """Simulate DReLU(⟨x⟩[k:m]) exactly: encode to the ring, secret-share
    with fresh randomness, drop bits, compute the reduced-ring sum's MSB.

    Returns a float 0/1 mask with the same semantics as the Rust engine's
    two-party protocol output (including Theorem 2's probabilistic pruning
    of values in [0, 2^m)).
    """
    w = k - m
    xi = jnp.round(x_f.astype(jnp.float64) * (2.0 ** frac_bits)).astype(jnp.int64)
    xu = xi.astype(U64)
    r = jax.random.bits(key, x_f.shape, dtype=U64)
    a0 = r
    a1 = xu - r
    t = ((a0 >> jnp.uint64(m)) + (a1 >> jnp.uint64(m))) & low_mask(w)
    sign = (t >> jnp.uint64(w - 1)) & jnp.uint64(1)
    return (jnp.uint64(1) - sign).astype(x_f.dtype)


def make_approx_relu_fn(plan_by_group, frac_bits, key):
    """relu_fn for forward_plain that applies a searched HummingBird plan.

    plan_by_group: {group: (k, m)}; straight-through gradient (the mask is
    treated as a constant), implementing the paper's finetuning (§4.1.3).
    """
    keys = {}

    def relu_fn(x, group):
        k, m = plan_by_group[group]
        if k == m:  # identity layer (zero bits retained)
            return x
        if (k, m) == (64, 0):
            return jnp.maximum(x, 0.0)
        gkey = jax.random.fold_in(key, group)
        mask = approx_drelu_mask(gkey, x, k, m, frac_bits)
        return x * jax.lax.stop_gradient(mask)

    return relu_fn
