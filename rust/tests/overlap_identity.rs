//! Bit-identity of the overlapped chunked schedule (DESIGN.md §10).
//!
//! The WAN pipeline (`gmw::pipeline`) reorders *when* rounds hit the wire,
//! never *what* is computed or sent: with overlap on or off, across both
//! binary layouts, with and without the prefetch offline phase, and for 2
//! and 3 parties, the per-party output shares, total wire bytes, round
//! count and per-phase byte split must all be identical. (Per-round trace
//! *order* differs — wave-major vs chunk-major — so totals are what is
//! pinned.)

use hummingbird::beaver::schedule::TripleSchedule;
use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties, run_parties_with, HarnessRun};
use hummingbird::gmw::kernels::{BitslicedKernels, RustKernels};
use hummingbird::gmw::ReluPlan;
use hummingbird::sharing::{reconstruct_arith, share_arith};

const N: usize = 256;
const CHUNKS: usize = 4;
const SEED: u64 = 9;

fn plan() -> ReluPlan {
    ReluPlan::new(12, 4).unwrap()
}

fn inputs(parties: usize) -> (Vec<u64>, Vec<Vec<u64>>) {
    let mut prg = Prg::new(0xAB, parties as u64);
    // Mixed signs and magnitudes on both sides of the plan's [m, k) window.
    let x: Vec<u64> = (0..N)
        .map(|i| {
            let v = (i as u64).wrapping_mul(97) % 4000;
            if i % 2 == 0 {
                v
            } else {
                v.wrapping_neg()
            }
        })
        .collect();
    let xs = share_arith(&mut prg, &x, parties);
    (x, xs)
}

/// The chunked run's dealer draws are chunk-major — CHUNKS consecutive
/// per-chunk ReLU schedules, the same with overlap on or off (the pipeline
/// pre-draws in serial order exactly so prefetch schedules stay valid).
fn chunked_schedule(parties: usize) -> TripleSchedule {
    let mut s = TripleSchedule::new();
    for _ in 0..CHUNKS {
        s.push_relu(N / CHUNKS, plan(), parties);
    }
    s
}

fn run_lane(
    parties: usize,
    xs: &[Vec<u64>],
    prefetch: bool,
    overlap: bool,
) -> HarnessRun<Vec<u64>> {
    let xs = xs.to_vec();
    run_parties(parties, SEED, move |p| {
        if prefetch {
            p.enable_prefetch(chunked_schedule(p.parties()), false);
        }
        let me = p.party();
        p.relu_chunked(&xs[me], plan(), CHUNKS, overlap).unwrap()
    })
}

fn run_sliced(
    parties: usize,
    xs: &[Vec<u64>],
    prefetch: bool,
    overlap: bool,
) -> HarnessRun<Vec<u64>> {
    let xs = xs.to_vec();
    run_parties_with(parties, SEED, |_| BitslicedKernels::default(), move |p| {
        if prefetch {
            p.enable_prefetch(chunked_schedule(p.parties()), false);
        }
        let me = p.party();
        p.relu_chunked(&xs[me], plan(), CHUNKS, overlap).unwrap()
    })
}

/// Like [`run_lane`] but with the kernel arm pinned to the always-scalar
/// reference (DESIGN.md §11) — `RustKernels::scalar()` bypasses both the
/// CLI choice and `HB_KERNEL`, so this is a genuine scalar run even when
/// the default-constructed arms above dispatch to AVX2.
fn run_lane_scalar(
    parties: usize,
    xs: &[Vec<u64>],
    prefetch: bool,
    overlap: bool,
) -> HarnessRun<Vec<u64>> {
    let xs = xs.to_vec();
    run_parties_with(parties, SEED, |_| RustKernels::scalar(), move |p| {
        if prefetch {
            p.enable_prefetch(chunked_schedule(p.parties()), false);
        }
        let me = p.party();
        p.relu_chunked(&xs[me], plan(), CHUNKS, overlap).unwrap()
    })
}

/// Forced-scalar twin of [`run_sliced`].
fn run_sliced_scalar(
    parties: usize,
    xs: &[Vec<u64>],
    prefetch: bool,
    overlap: bool,
) -> HarnessRun<Vec<u64>> {
    let xs = xs.to_vec();
    run_parties_with(parties, SEED, |_| BitslicedKernels::scalar(), move |p| {
        if prefetch {
            p.enable_prefetch(chunked_schedule(p.parties()), false);
        }
        let me = p.party();
        p.relu_chunked(&xs[me], plan(), CHUNKS, overlap).unwrap()
    })
}

fn assert_identical(a: &HarnessRun<Vec<u64>>, b: &HarnessRun<Vec<u64>>, label: &str) {
    assert_eq!(a.outputs, b.outputs, "{label}: per-party output shares diverged");
    assert_eq!(a.trace.total_bytes(), b.trace.total_bytes(), "{label}: wire bytes");
    assert_eq!(a.trace.total_rounds(), b.trace.total_rounds(), "{label}: round count");
    assert_eq!(a.trace.bytes_by_phase(), b.trace.bytes_by_phase(), "{label}: bytes by phase");
    assert_eq!(a.trace.rounds_by_phase(), b.trace.rounds_by_phase(), "{label}: rounds by phase");
}

/// overlap on/off × prefetch on/off × {2, 3} parties, lane layout.
#[test]
fn overlap_matches_serial_lane() {
    for parties in [2usize, 3] {
        let (_, xs) = inputs(parties);
        for prefetch in [false, true] {
            let serial = run_lane(parties, &xs, prefetch, false);
            let overlapped = run_lane(parties, &xs, prefetch, true);
            let label = format!("lane p{parties} prefetch={prefetch}");
            assert_identical(&serial, &overlapped, &label);
        }
    }
}

/// overlap on/off × prefetch on/off × {2, 3} parties, bitsliced layout —
/// and the layouts themselves must agree, so the overlapped bitsliced run
/// is compared against the serial *lane* run too (strongest cross-check).
#[test]
fn overlap_matches_serial_bitsliced_and_cross_layout() {
    for parties in [2usize, 3] {
        let (_, xs) = inputs(parties);
        let lane_serial = run_lane(parties, &xs, false, false);
        for prefetch in [false, true] {
            let serial = run_sliced(parties, &xs, prefetch, false);
            let overlapped = run_sliced(parties, &xs, prefetch, true);
            let label = format!("bitsliced p{parties} prefetch={prefetch}");
            assert_identical(&serial, &overlapped, &label);
            assert_identical(&lane_serial, &overlapped, &format!("{label} vs lane"));
        }
    }
}

/// Kernel axis (DESIGN.md §11): scalar × dispatched(auto) × layout ×
/// prefetch × overlap × {2, 3} parties. The overlapped WAN schedule must
/// stay bit-identical when the kernel arm changes underneath it — same
/// shares, same byte/round totals, same per-phase split — and the
/// forced-scalar runs of both layouts must agree with each other.
#[test]
fn overlap_identity_holds_across_kernel_arms() {
    for parties in [2usize, 3] {
        let (_, xs) = inputs(parties);
        for prefetch in [false, true] {
            for overlap in [false, true] {
                let label = format!("kernel p{parties} prefetch={prefetch} overlap={overlap}");
                let lane_auto = run_lane(parties, &xs, prefetch, overlap);
                let lane_scalar = run_lane_scalar(parties, &xs, prefetch, overlap);
                assert_identical(&lane_scalar, &lane_auto, &format!("{label} lane"));
                let sliced_auto = run_sliced(parties, &xs, prefetch, overlap);
                let sliced_scalar = run_sliced_scalar(parties, &xs, prefetch, overlap);
                assert_identical(&sliced_scalar, &sliced_auto, &format!("{label} bitsliced"));
                assert_identical(&lane_scalar, &sliced_scalar, &format!("{label} cross-layout"));
            }
        }
    }
}

/// The overlapped schedule must also still compute the right function:
/// reconstructed outputs equal the engine's own unchunked ReLU (chunking
/// legitimately re-apportions PRG streams, so shares differ from the
/// unchunked run — clear values may not).
#[test]
fn overlapped_clear_values_match_unchunked_relu() {
    let parties = 2;
    let (_, xs) = inputs(parties);
    let xs2 = xs.clone();
    let unchunked = run_parties(parties, SEED, move |p| {
        let me = p.party();
        p.relu(&xs2[me], plan()).unwrap()
    });
    let overlapped = run_lane(parties, &xs, false, true);
    assert_eq!(
        reconstruct_arith(&overlapped.outputs),
        reconstruct_arith(&unchunked.outputs),
        "overlapped chunked ReLU computes a different function"
    );
}

/// DReLU (no Beaver-mult epilogue) through the same matrix, 3 parties.
#[test]
fn drelu_overlap_matches_serial() {
    let parties = 3;
    let (_, xs) = inputs(parties);
    let xs_a = xs.clone();
    let serial = run_parties(parties, SEED, move |p| {
        let me = p.party();
        p.drelu_chunked(&xs_a[me], plan(), CHUNKS, false).unwrap()
    });
    let xs_b = xs.clone();
    let overlapped = run_parties(parties, SEED, move |p| {
        let me = p.party();
        p.drelu_chunked(&xs_b[me], plan(), CHUNKS, true).unwrap()
    });
    assert_identical(&serial, &overlapped, "drelu p3");
}
