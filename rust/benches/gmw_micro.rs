//! Microbenchmarks of the GMW engine's building blocks: AND gates, the
//! Kogge–Stone adder, A2B, B2A, Beaver mult — across ring widths. These are
//! the per-operation numbers behind every end-to-end figure; run with
//! `cargo bench --bench gmw_micro` (HB_BENCH_QUICK=1 for a fast pass).

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::{run_parties, run_parties_threaded};
use hummingbird::gmw::{adder, ReluPlan};
use hummingbird::sharing::{share_arith, share_binary};
use hummingbird::util::benchkit::{bench_threads, Bench};

fn main() {
    let mut bench = Bench::new();
    let n = 16384usize;
    let mut prg = Prg::new(1, 1);
    let x: Vec<u64> = prg.vec_u64(n);
    let xs_a = share_arith(&mut prg, &x, 2);
    let xs_b = share_binary(&mut prg, &x, 2);
    let ys_b = share_binary(&mut prg, &x, 2);

    // Secure AND on full words.
    {
        let xs = xs_b.clone();
        let ys = ys_b.clone();
        bench.bench_elems(&format!("and_gates/64bit/{n}"), n as u64, || {
            let xs = xs.clone();
            let ys = ys.clone();
            run_parties(2, 3, move |p| {
                let me = p.party();
                p.and_gates(
                    hummingbird::net::accounting::Phase::Circuit,
                    &xs[me],
                    &ys[me],
                    64,
                )
                .unwrap()
            });
        });
    }

    // Kogge–Stone adder across widths (the O(w log w) law).
    for w in [64u32, 20, 8, 6] {
        let mask = hummingbird::ring::low_mask(w);
        let xs: Vec<Vec<u64>> =
            xs_b.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
        let ys: Vec<Vec<u64>> =
            ys_b.iter().map(|s| s.iter().map(|v| v & mask).collect()).collect();
        bench.bench_elems(&format!("ks_add/w{w}/{n}"), n as u64, || {
            let xs = xs.clone();
            let ys = ys.clone();
            run_parties(2, 4, move |p| {
                let me = p.party();
                adder::ks_add(p, &xs[me], &ys[me], w).unwrap()
            });
        });
    }

    // Full DReLU at paper-relevant windows.
    for (label, plan) in [
        ("baseline64", ReluPlan::BASELINE),
        ("eco18", ReluPlan::new(18, 0).unwrap()),
        ("hb8", ReluPlan::new(12, 4).unwrap()),
        ("hb6", ReluPlan::new(10, 4).unwrap()),
    ] {
        let xs = xs_a.clone();
        bench.bench_elems(&format!("drelu/{label}/{n}"), n as u64, || {
            let xs = xs.clone();
            run_parties(2, 5, move |p| {
                let me = p.party();
                p.drelu(&xs[me], plan).unwrap()
            });
        });
    }

    // Plane-native Beaver triple expansion (the offline dealer cost): the
    // stream draws only the w live bit-planes per 64-lane block, so the
    // w6 row should run ~10x the w64 row's throughput.
    {
        use hummingbird::beaver::TtpDealer;
        use hummingbird::gmw::bitsliced::plane_len;
        use hummingbird::util::benchkit::black_box;
        for w in [6u32, 64] {
            let pl = plane_len(n, w);
            let mut a = vec![0u64; pl];
            let mut b = vec![0u64; pl];
            let mut c = vec![0u64; pl];
            let mut dealer = TtpDealer::new(3, 0, 2);
            bench.bench_elems(&format!("bin_triples_planes/w{w}/{n}"), n as u64, || {
                dealer.bin_triples_planes_into(w, n, 1, &mut a, &mut b, &mut c);
                black_box(&c);
            });
        }
    }

    // Beaver arithmetic multiplication (the incompressible Mult phase).
    {
        let xs = xs_a.clone();
        let ys = share_arith(&mut prg, &x, 2);
        bench.bench_elems(&format!("beaver_mult/{n}"), n as u64, || {
            let xs = xs.clone();
            let ys = ys.clone();
            run_parties(2, 6, move |p| {
                let me = p.party();
                p.mul(&xs[me], &ys[me]).unwrap()
            });
        });
    }

    // B2A via daBits.
    {
        let bits: Vec<u64> = x.iter().map(|v| v & 1).collect();
        let bs = share_binary(&mut prg, &bits, 2);
        let bs: Vec<Vec<u64>> = bs.iter().map(|s| s.iter().map(|v| v & 1).collect()).collect();
        bench.bench_elems(&format!("b2a_bit/{n}"), n as u64, || {
            let bs = bs.clone();
            run_parties(2, 7, move |p| {
                let me = p.party();
                p.b2a_bit(&bs[me]).unwrap()
            });
        });
    }

    // Hot path at scale: n = 65536, single-threaded vs multi-threaded
    // (the zero-allocation arena + parallel kernels + fused bitpack path;
    // perf target: >= 1.5x at this size on multi-core hosts, no regression
    // at the small sizes above, which all run t=1).
    {
        let n_big = 65536usize;
        let threads = bench_threads();
        let xb: Vec<u64> = (0..n_big).map(|_| prg.next_u64() % (1 << 16)).collect();
        let xs_big = share_arith(&mut prg, &xb, 2);
        let ub = share_binary(&mut prg, &xb, 2);
        let vb = share_binary(&mut prg, &xb, 2);
        let plan = ReluPlan::new(12, 4).unwrap();
        for t in [1usize, threads] {
            // Shares are borrowed, not cloned, inside the timed closures:
            // a per-iteration multi-MB memcpy would dilute the t1-vs-tN
            // comparison these rows exist to make.
            bench.bench_elems(&format!("and_gates/64bit/{n_big}/t{t}"), n_big as u64, || {
                run_parties_threaded(2, 21, t, |p| {
                    let me = p.party();
                    p.and_gates(
                        hummingbird::net::accounting::Phase::Circuit,
                        &ub[me],
                        &vb[me],
                        64,
                    )
                    .unwrap()
                });
            });
            bench.bench_elems(&format!("relu/hb8/{n_big}/t{t}"), n_big as u64, || {
                run_parties_threaded(2, 22, t, |p| {
                    let me = p.party();
                    p.relu(&xs_big[me], plan).unwrap()
                });
            });
            if threads == 1 {
                break; // single-core host: the two rows would be identical
            }
        }
    }

    bench.dump_json("gmw_micro");
}
