//! Chaos soak for the overload-safe serving core (DESIGN.md §9).
//!
//! Pushes thousands of requests through the coordinator at deliberate
//! overload while a seeded, randomized [`FaultProfile`] injects delays,
//! link drops and crashes, then pins the service-level invariants:
//!
//! * the service never wedges (every response arrives within a generous
//!   bound, enforced with `recv_timeout`);
//! * the admission accounting identity is *exact* — every client-visible
//!   outcome is cross-checked against the coordinator's own counters and
//!   `MetricsSnapshot::balanced()` holds;
//! * memory stays bounded — the global thread-pool worker count plateaus
//!   after warm-up and every party thread is reaped by shutdown
//!   (`live_party_threads == 0`);
//! * a forced crash loop reaches `Degraded` within the restart budget and
//!   recovers to `Serving`, with all breaker timing on a mock clock;
//! * completed results are bit-identical across `--layout lane|bitsliced`
//!   × `--prefetch on|off` under the same fault schedule.
//!
//! Requires artifacts + micronet weights (skips otherwise). The request
//! volume scales with `HB_SOAK_REQUESTS` (default 2000; CI smoke sets
//! 200).

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

use hummingbird::coordinator::{
    ClockHandle, Coordinator, InferenceResult, LifecycleState, ServeOptions,
};
use hummingbird::error::Error;
use hummingbird::gmw::kernels::BinLayout;
use hummingbird::hummingbird::PlanSet;
use hummingbird::model::{Dataset, ModelConfig};
use hummingbird::net::fault::FaultProfile;
use hummingbird::util::threadpool::pool_workers_spawned;

const MODEL: &str = "micronet_synth10";

/// An in-flight response handle, as returned by `Coordinator::infer_async`.
type Rx = Receiver<hummingbird::Result<InferenceResult>>;

/// Answering a single request can legitimately take a while under
/// injected delays and respawn backoff; anything beyond this is a wedge.
const WEDGE: Duration = Duration::from_secs(120);

fn ready() -> Option<std::path::PathBuf> {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf();
    if repo.join("artifacts/manifest.json").exists()
        && repo.join(format!("artifacts/weights/{MODEL}.json")).exists()
    {
        Some(repo)
    } else {
        eprintln!("skipping: artifacts/weights missing");
        None
    }
}

/// Total request volume for the soak (split across seeded runs).
fn soak_requests() -> usize {
    std::env::var("HB_SOAK_REQUESTS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 8)
        .unwrap_or(2000)
}

/// Client-side tally of terminal request dispositions, mirrored 1:1
/// against the coordinator's [`AdmissionCounters`] at the end of a run.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
struct ClientTally {
    admitted: u64,
    shed_at_admission: u64,
    completed: u64,
    deadline: u64,
    failed: u64,
}

/// Settle one in-flight response, classifying its outcome; panics (wedge)
/// if nothing arrives within [`WEDGE`].
fn settle(rx: Rx, tally: &mut ClientTally) {
    match rx.recv_timeout(WEDGE) {
        Ok(Ok(_)) => tally.completed += 1,
        Ok(Err(Error::Deadline(_))) => tally.deadline += 1,
        Ok(Err(_)) => tally.failed += 1,
        Err(RecvTimeoutError::Timeout) => panic!("coordinator wedged: no response in {WEDGE:?}"),
        Err(RecvTimeoutError::Disconnected) => panic!("response channel dropped unanswered"),
    }
}

/// One seeded overload run: submit `n` requests back-to-back against a
/// tiny queue; every queue-full rejection settles the oldest in-flight
/// request, so submission is paced by completion while the queue stays
/// saturated (sheds are guaranteed, and so is progress).
fn overload_run(repo: &std::path::Path, dataset: &Dataset, seed: u64, n: usize) -> ClientTally {
    let cfg = ModelConfig::load_named(repo, MODEL).unwrap();
    let mut opts = ServeOptions::new(repo, MODEL);
    opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
    opts.queue_depth = 4;
    opts.batch_timeout = Duration::from_millis(2);
    // Generous deadline: exercises the stamping/shedding path on every
    // request without (normally) expiring anything.
    opts.request_timeout = Some(Duration::from_secs(60));
    let profile = format!("party:1,seed:{seed},delay:2ms@?12,drop@?30,crash@?60");
    opts.fault_profile = Some(profile.parse::<FaultProfile>().unwrap());
    let svc = Coordinator::start(opts).unwrap();

    let mut tally = ClientTally::default();
    let mut outstanding: VecDeque<Rx> = VecDeque::new();
    for i in 0..n {
        let sample = i % 8;
        match svc.infer_async(dataset.test.batch(sample, sample + 1).to_vec()) {
            Ok(rx) => {
                tally.admitted += 1;
                outstanding.push_back(rx);
            }
            Err(e) if matches!(e, Error::Overloaded(_)) => {
                assert!(e.client_should_retry());
                tally.shed_at_admission += 1;
                // Make room before the next submission.
                if let Some(rx) = outstanding.pop_front() {
                    settle(rx, &mut tally);
                } else {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            Err(e) => panic!("unexpected admission failure: {e}"),
        }
    }
    for rx in outstanding {
        settle(rx, &mut tally);
    }

    // Every request was answered before shutdown, so the drain finds an
    // empty queue and the counters must mirror the client tally exactly.
    let snap = svc.shutdown_with_deadline(Duration::from_secs(30));
    let a = snap.admission;
    assert_eq!(a.admitted, tally.admitted, "admitted mismatch: {a:?} vs {tally:?}");
    assert_eq!(
        a.shed_queue_full + a.rejected_degraded,
        tally.shed_at_admission,
        "shed mismatch: {a:?} vs {tally:?}"
    );
    assert_eq!(a.completed, tally.completed, "completed mismatch: {a:?} vs {tally:?}");
    assert_eq!(a.shed_deadline, tally.deadline, "deadline mismatch: {a:?} vs {tally:?}");
    assert_eq!(a.failed_requests, tally.failed, "failure mismatch: {a:?} vs {tally:?}");
    assert_eq!(a.drained, 0, "nothing was left to drain: {a:?}");
    assert!(snap.balanced(), "identity must hold: {a:?}");
    assert_eq!(snap.state, LifecycleState::Stopped);
    assert_eq!(snap.live_party_threads, 0, "orphaned party threads after drain");
    tally
}

/// Tentpole soak: seeded randomized fault schedules at deliberate
/// overload — never wedges, exact accounting, bounded memory, clean
/// drains.
#[test]
fn soak_identity_under_randomized_faults() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let total = soak_requests();
    let seeds: [u64; 2] = [7, 1312];
    let per_run = (total / seeds.len()).max(8);

    let mut grand = ClientTally::default();
    let mut workers_after_warmup = 0usize;
    for (k, seed) in seeds.iter().enumerate() {
        let t = overload_run(&repo, &dataset, *seed, per_run);
        assert!(t.completed > 0, "seed {seed}: overload starved all requests: {t:?}");
        grand.completed += t.completed;
        grand.shed_at_admission += t.shed_at_admission;
        if k == 0 {
            // The global pool is initialized by the first run; it must
            // not grow afterwards (memory plateau).
            workers_after_warmup = pool_workers_spawned();
            assert!(workers_after_warmup > 0, "pool never initialized");
        }
    }
    assert_eq!(
        pool_workers_spawned(),
        workers_after_warmup,
        "thread-pool grew after warm-up: memory is not plateauing"
    );
    assert!(
        grand.shed_at_admission > 0,
        "the soak never overloaded the queue — not a meaningful test: {grand:?}"
    );
    eprintln!("soak: {grand:?} over {} requests", per_run * seeds.len());
}

/// Forced crash loop through the soak harness: boot failures exhaust the
/// restart budget within `max_restarts`, the coordinator degrades (and
/// says so to clients), the background probe — driven entirely by a mock
/// clock — revives it, and a post-recovery burst completes cleanly.
#[test]
fn soak_crash_loop_reaches_degraded_and_recovers() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
    opts.max_restarts = 4;
    // 4 failures trip the breaker, the next 3 fail the probes, then boot.
    opts.fault_profile = Some(FaultProfile::boot_failures(7));
    let (clock, mock) = ClockHandle::mock();
    opts.clock = clock;
    let svc = Coordinator::start(opts).unwrap();

    let t0 = std::time::Instant::now();
    while svc.metrics.state() != LifecycleState::Degraded {
        assert!(t0.elapsed() < WEDGE, "breaker never tripped");
        std::thread::sleep(Duration::from_millis(5));
    }
    let err = svc.infer(dataset.test.batch(0, 1).to_vec()).unwrap_err();
    assert!(matches!(err, Error::Overloaded(_)), "degraded must shed, got {err}");

    while svc.metrics.state() != LifecycleState::Serving {
        assert!(t0.elapsed() < WEDGE, "probe never recovered the service");
        mock.advance(Duration::from_millis(500));
        std::thread::sleep(Duration::from_millis(5));
    }
    let burst = soak_requests().min(24);
    let mut rxs = Vec::new();
    for i in 0..burst {
        let sample = i % 8;
        rxs.push(svc.infer_async(dataset.test.batch(sample, sample + 1).to_vec()).unwrap());
    }
    for rx in rxs {
        let r = rx.recv_timeout(WEDGE).unwrap().unwrap();
        assert_eq!(r.logits.len(), cfg.num_classes);
    }

    let snap = svc.shutdown_with_deadline(Duration::from_secs(30));
    assert!(snap.admission.rejected_degraded >= 1, "degraded shed uncounted: {snap:?}");
    assert_eq!(snap.admission.completed, burst as u64);
    assert!(snap.balanced(), "identity must hold: {:?}", snap.admission);
    assert_eq!(snap.state, LifecycleState::Stopped);
    assert_eq!(snap.live_party_threads, 0);
}

/// Completed predictions are bit-identical across `--layout` ×
/// `--prefetch` under the same seeded fault schedule. Faulted batches may
/// differ per combo (a drop fails whichever requests shared the batch),
/// so the comparison runs over the intersection of completed indices.
#[test]
fn soak_bit_identity_across_layout_and_prefetch() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();
    let n = (soak_requests() / 50).clamp(12, 48);

    let run = |layout: BinLayout, prefetch: bool| -> BTreeMap<usize, usize> {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
        opts.layout = layout;
        opts.prefetch = prefetch;
        // No admission pressure here: the subject is result identity.
        opts.queue_depth = n.max(1);
        opts.fault_profile =
            Some("party:1,seed:11,delay:2ms@?10,drop@?25".parse::<FaultProfile>().unwrap());
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..n {
            let sample = i % 8;
            rxs.push((i, svc.infer_async(dataset.test.batch(sample, sample + 1).to_vec())));
        }
        let mut preds = BTreeMap::new();
        for (i, rx) in rxs {
            if let Ok(Ok(r)) = rx.unwrap().recv_timeout(WEDGE) {
                preds.insert(i, r.pred);
            }
        }
        let snap = svc.shutdown_with_deadline(Duration::from_secs(30));
        assert!(snap.balanced(), "identity must hold: {:?}", snap.admission);
        preds
    };

    let combos = [
        (BinLayout::LanePerU64, false),
        (BinLayout::LanePerU64, true),
        (BinLayout::Bitsliced, false),
        (BinLayout::Bitsliced, true),
    ];
    let results: Vec<BTreeMap<usize, usize>> = combos.iter().map(|&(l, p)| run(l, p)).collect();

    // Intersection of indices completed by every combo.
    let common: Vec<usize> = results[0]
        .keys()
        .copied()
        .filter(|i| results.iter().all(|m| m.contains_key(i)))
        .collect();
    assert!(
        common.len() >= n / 2,
        "too few commonly-completed requests ({} of {n}) to compare",
        common.len()
    );
    for (k, m) in results.iter().enumerate().skip(1) {
        for &i in &common {
            let want = results[0][&i];
            assert_eq!(m[&i], want, "request {i}: {:?} vs {:?}", combos[k], combos[0]);
        }
    }
}
