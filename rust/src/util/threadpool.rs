//! Scoped data-parallel helpers (rayon is not available offline).
//!
//! Built on `std::thread::scope`. The pool size defaults to the number of
//! available CPUs; on single-core testbeds the helpers degrade gracefully to
//! sequential execution with zero spawn overhead.
//!
//! These helpers back the GMW hot path: [`par_chunks_mut`] drives the
//! buffer-writing kernels and the fused bitpack/unpack (`gmw::kernels`,
//! `bitpack`), while [`par_chunks`] remains the generic index-range splitter.
//! All of them produce results identical to the single-threaded loop for any
//! thread count — the protocol depends on that for bit-exactness.

/// Number of worker threads to use for data-parallel loops.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// chunks across up to `threads` OS threads. `f` must be `Send + Sync`.
///
/// Returns after all chunks complete (scoped threads). With `threads <= 1`
/// or tiny `n` this runs inline on the caller's thread.
pub fn par_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    // Spawn chunks 1.. and run chunk 0 on the calling thread: `threads`
    // workers cost `threads - 1` spawns and the caller's core does its
    // share instead of blocking idle in the scope.
    std::thread::scope(|s| {
        for t in 1..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
        f(0, 0..chunk.min(n));
    });
}

/// Split `data` into contiguous chunks and run `f(offset, chunk)` on up to
/// `threads` OS threads. Safe (no aliasing): each chunk is a disjoint
/// `&mut` sub-slice obtained via `split_at_mut`. `offset` is the index of
/// the chunk's first element in `data`, so `f` can read companion input
/// slices at the matching positions.
///
/// This is the write-side workhorse of the zero-allocation GMW hot path:
/// kernels and the fused bitpack use it to fill caller-provided buffers in
/// parallel without any per-call allocation beyond the scoped threads.
pub fn par_chunks_mut<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    let n = data.len();
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 || n < 2 {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    // First chunk runs on the calling thread (see par_chunks).
    let (first, mut rest) = data.split_at_mut(chunk.min(n));
    std::thread::scope(|s| {
        let f = &f;
        let mut offset = first.len();
        while !rest.is_empty() {
            let take = chunk.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let off = offset;
            offset += take;
            s.spawn(move || f(off, head));
        }
        f(0, first);
    });
}

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send + Default + Clone,
    F: Fn(&T) -> U + Send + Sync,
{
    let mut out = vec![U::default(); items.len()];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        par_chunks(items.len(), threads, move |_, range| {
            for i in range {
                // SAFETY: each index is written by exactly one chunk.
                unsafe { *out_ref.get().add(i) = f(&items[i]) };
            }
        });
    }
    out
}

/// Wrapper to allow sharing a raw pointer across scoped threads when the
/// access pattern is provably disjoint (each index written by exactly one
/// chunk). Used by [`par_map`] and by `bitpack`'s parallel word packer,
/// where output regions are word-disjoint but not representable as `&mut`
/// sub-slices of equal element type. Deliberately `pub(crate)`: the
/// unconditional `Send`/`Sync` impls launder the disjointness obligation,
/// so the contract must stay auditable within this crate.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

impl<T> SendPtr<T> {
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

// SAFETY: callers guarantee disjoint access per chunk (documented above).
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_exactly_once() {
        let n = 1037;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n, 4, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..501).collect();
        let out = par_map(&items, 3, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        par_chunks(0, 4, |_, r| assert!(r.is_empty()));
        let out = par_map::<usize, usize, _>(&[], 4, |x| *x);
        assert!(out.is_empty());
        let out = par_map(&[7usize], 4, |x| x + 1);
        assert_eq!(out, vec![8]);
    }

    /// Hot-path contract: for every thread count the helpers must produce
    /// output identical to the single-threaded reference loop. This is what
    /// the GMW kernels and the fused bitpack rely on for bit-exactness.
    #[test]
    fn par_chunks_matches_single_threaded_reference() {
        for n in [0usize, 1, 2, 3, 1000, 1037] {
            let input: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(0x9e37_79b9)).collect();
            let reference: Vec<u64> =
                input.iter().enumerate().map(|(i, v)| v ^ (i as u64)).collect();
            for threads in [1usize, 2, default_threads()] {
                let out: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                par_chunks(n, threads, |_, range| {
                    for i in range {
                        out[i].store((input[i] ^ (i as u64)) as usize, Ordering::Relaxed);
                    }
                });
                let got: Vec<u64> =
                    out.iter().map(|a| a.load(Ordering::Relaxed) as u64).collect();
                assert_eq!(got, reference, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn par_chunks_mut_matches_reference_all_thread_counts() {
        for n in [0usize, 1, 5, 1024, 4099] {
            let input: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(31)).collect();
            let reference: Vec<u64> = input.iter().map(|v| v.wrapping_add(7)).collect();
            for threads in [1usize, 2, 3, default_threads()] {
                let mut out = vec![0u64; n];
                par_chunks_mut(&mut out, threads, |off, chunk| {
                    for (i, o) in chunk.iter_mut().enumerate() {
                        *o = input[off + i].wrapping_add(7);
                    }
                });
                assert_eq!(out, reference, "n={n} threads={threads}");
            }
        }
    }

    /// `n < threads` must neither panic nor drop elements.
    #[test]
    fn more_threads_than_items() {
        let n = 3;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n, 16, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));

        let mut out = vec![0u8; 2];
        par_chunks_mut(&mut out, 64, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = (off + i) as u8 + 1;
            }
        });
        assert_eq!(out, vec![1, 2]);
    }
}
