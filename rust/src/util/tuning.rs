//! Central registry of the engine's parallelism/tuning thresholds.
//!
//! PR 1 scattered two "don't parallelize below this size" constants across
//! `bitpack` and `gmw::kernels`; the bitsliced layout (PR 3) adds a third.
//! They all live here now, each overridable through an environment variable
//! so bench sweeps can explore the thresholds **without recompiling**:
//!
//! | knob                 | env var              | default | guards                                   |
//! |----------------------|----------------------|---------|------------------------------------------|
//! | [`par_min_lanes`]    | `HB_PAR_MIN_LANES`   | 8192    | lane-wise kernels, `unpack_bytes_xor_into` |
//! | [`par_min_words`]    | `HB_PAR_MIN_WORDS`   | 2048    | `pack_bytes_into` (packed-word count)    |
//! | [`par_min_blocks`]   | `HB_PAR_MIN_BLOCKS`  | 64      | bitsliced transpose/pack (64-lane blocks) |
//! | [`simd_min_words`]   | `HB_SIMD_MIN_WORDS`  | 8       | AVX2 dispatch floor for plane kernels (DESIGN.md §11) |
//! | [`kernel_override`]  | `HB_KERNEL`          | unset   | forces the kernel arm (`scalar`/`simd`/`auto`) over CLI/config |
//!
//! Values are read **once** on first use and cached for the process
//! lifetime (a `OnceLock`), so the hot path pays one atomic load — set the
//! variables before the first protocol round. Unparseable or zero values
//! fall back to the default (a threshold of 0 would make single-element
//! buffers spawn pool regions; use `1` to force parallelism everywhere).
//!
//! These thresholds only trade dispatch overhead against parallel speedup:
//! every guarded code path produces bit-identical results at any setting.

use std::sync::OnceLock;

/// Default minimum lane count before lane-wise loops go parallel.
pub const DEFAULT_PAR_MIN_LANES: usize = 8192;
/// Default minimum packed-word count before the fused bitpack goes parallel.
pub const DEFAULT_PAR_MIN_WORDS: usize = 2048;
/// Default minimum 64-lane block count before bitsliced transposes go
/// parallel (one block is 64 lanes, so 64 blocks = 4096 lanes).
pub const DEFAULT_PAR_MIN_BLOCKS: usize = 64;
/// Default minimum u64 word count before the plane kernels take the AVX2
/// arm: below this the 4-wide main loop degenerates to all-tail and the
/// detection branch is pure overhead (DESIGN.md §11).
pub const DEFAULT_SIMD_MIN_WORDS: usize = 8;

/// The resolved thresholds (env overrides applied).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tuning {
    pub par_min_lanes: usize,
    pub par_min_words: usize,
    pub par_min_blocks: usize,
    pub simd_min_words: usize,
}

impl Default for Tuning {
    fn default() -> Self {
        Tuning {
            par_min_lanes: DEFAULT_PAR_MIN_LANES,
            par_min_words: DEFAULT_PAR_MIN_WORDS,
            par_min_blocks: DEFAULT_PAR_MIN_BLOCKS,
            simd_min_words: DEFAULT_SIMD_MIN_WORDS,
        }
    }
}

/// Parse one override: `None` / empty / unparseable / zero → `default`.
fn parse_override(raw: Option<&str>, default: usize) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok()).filter(|v| *v > 0).unwrap_or(default)
}

fn from_env() -> Tuning {
    let lanes = std::env::var("HB_PAR_MIN_LANES").ok();
    let words = std::env::var("HB_PAR_MIN_WORDS").ok();
    let blocks = std::env::var("HB_PAR_MIN_BLOCKS").ok();
    let simd = std::env::var("HB_SIMD_MIN_WORDS").ok();
    Tuning {
        par_min_lanes: parse_override(lanes.as_deref(), DEFAULT_PAR_MIN_LANES),
        par_min_words: parse_override(words.as_deref(), DEFAULT_PAR_MIN_WORDS),
        par_min_blocks: parse_override(blocks.as_deref(), DEFAULT_PAR_MIN_BLOCKS),
        simd_min_words: parse_override(simd.as_deref(), DEFAULT_SIMD_MIN_WORDS),
    }
}

static TUNING: OnceLock<Tuning> = OnceLock::new();
static KERNEL_OVERRIDE: OnceLock<Option<String>> = OnceLock::new();

/// The process-wide tuning snapshot (env read once, then cached).
pub fn tuning() -> Tuning {
    *TUNING.get_or_init(from_env)
}

/// Lane count below which lane-wise kernels stay single-threaded.
#[inline]
pub fn par_min_lanes() -> usize {
    tuning().par_min_lanes
}

/// Packed-word count below which the fused bitpack stays single-threaded.
#[inline]
pub fn par_min_words() -> usize {
    tuning().par_min_words
}

/// 64-lane block count below which bitsliced transposes stay
/// single-threaded.
#[inline]
pub fn par_min_blocks() -> usize {
    tuning().par_min_blocks
}

/// u64 word count below which plane kernels skip the AVX2 arm
/// (DESIGN.md §11).
#[inline]
pub fn simd_min_words() -> usize {
    tuning().simd_min_words
}

/// The raw `HB_KERNEL` override, read once and cached (non-empty trimmed
/// value, or `None` when unset/blank). Parsing lives in
/// `gmw::kernels::KernelChoice` — this module only owns the env read so
/// the snapshot discipline matches the numeric knobs above.
pub fn kernel_override() -> Option<&'static str> {
    KERNEL_OVERRIDE
        .get_or_init(|| {
            std::env::var("HB_KERNEL")
                .ok()
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
        })
        .as_deref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_overrides() {
        assert_eq!(parse_override(None, 8192), 8192);
        assert_eq!(Tuning::default().par_min_lanes, DEFAULT_PAR_MIN_LANES);
        assert_eq!(Tuning::default().par_min_words, DEFAULT_PAR_MIN_WORDS);
        assert_eq!(Tuning::default().par_min_blocks, DEFAULT_PAR_MIN_BLOCKS);
        assert_eq!(Tuning::default().simd_min_words, DEFAULT_SIMD_MIN_WORDS);
    }

    #[test]
    fn override_parsing() {
        assert_eq!(parse_override(Some("123"), 1), 123);
        assert_eq!(parse_override(Some(" 64 "), 1), 64);
        // Garbage, empty and zero all fall back to the default.
        assert_eq!(parse_override(Some("banana"), 7), 7);
        assert_eq!(parse_override(Some(""), 7), 7);
        assert_eq!(parse_override(Some("0"), 7), 7);
    }

    /// The cached accessor must agree with itself (and be >= 1 so the
    /// threadpool never sees a zero threshold), whatever the test
    /// environment set.
    #[test]
    fn cached_snapshot_is_stable_and_positive() {
        let a = tuning();
        let b = tuning();
        assert_eq!(a, b);
        assert!(a.par_min_lanes >= 1 && a.par_min_words >= 1 && a.par_min_blocks >= 1);
        assert!(a.simd_min_words >= 1);
        assert_eq!(par_min_lanes(), a.par_min_lanes);
        assert_eq!(par_min_words(), a.par_min_words);
        assert_eq!(par_min_blocks(), a.par_min_blocks);
        assert_eq!(simd_min_words(), a.simd_min_words);
    }

    /// `kernel_override` is a cached raw string: stable across calls, and
    /// never the empty string (blank values collapse to `None`).
    #[test]
    fn kernel_override_snapshot_is_stable() {
        let a = kernel_override();
        let b = kernel_override();
        assert_eq!(a, b);
        if let Some(v) = a {
            assert!(!v.is_empty());
            assert_eq!(v, v.trim());
        }
    }
}
