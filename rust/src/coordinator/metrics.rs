//! Serving metrics: request latency, throughput, communication and the
//! compute/communication breakdown used by Figs 1 & 10.

use std::sync::Mutex;
use std::time::Instant;

use crate::model::ExecBreakdown;
use crate::util::json::Json;
use crate::util::stats;

/// Accumulated serving metrics (thread-safe).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    request_latencies_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    samples_done: u64,
    batches_done: u64,
    breakdown: ExecBreakdown,
    started: Option<Instant>,
    finished: Option<Instant>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn mark_start(&self) {
        let mut m = self.inner.lock().unwrap();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
    }

    pub fn record_batch(&self, batch: usize, latency_s: f64, bd: &ExecBreakdown) {
        let mut m = self.inner.lock().unwrap();
        m.batch_sizes.push(batch);
        m.samples_done += batch as u64;
        m.batches_done += 1;
        m.breakdown.add(bd);
        m.finished = Some(Instant::now());
        for _ in 0..batch {
            m.request_latencies_s.push(latency_s);
        }
    }

    pub fn samples_done(&self) -> u64 {
        self.inner.lock().unwrap().samples_done
    }

    /// Wall-clock between first and last batch.
    pub fn wall_seconds(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        match (m.started, m.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn throughput(&self) -> f64 {
        let w = self.wall_seconds();
        if w <= 0.0 {
            0.0
        } else {
            self.samples_done() as f64 / w
        }
    }

    pub fn breakdown(&self) -> ExecBreakdown {
        self.inner.lock().unwrap().breakdown
    }

    pub fn to_json(&self) -> Json {
        let m = self.inner.lock().unwrap();
        Json::obj(vec![
            ("samples", Json::Int(m.samples_done as i64)),
            ("batches", Json::Int(m.batches_done as i64)),
            ("p50_latency_s", Json::Num(stats::median(&m.request_latencies_s))),
            ("p95_latency_s", Json::Num(stats::percentile(&m.request_latencies_s, 95.0))),
            ("linear_s", Json::Num(m.breakdown.linear_s)),
            ("relu_s", Json::Num(m.breakdown.relu_s)),
            ("other_s", Json::Num(m.breakdown.other_s)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.mark_start();
        let bd = ExecBreakdown { linear_s: 0.5, relu_s: 1.0, other_s: 0.1 };
        m.record_batch(4, 0.2, &bd);
        m.record_batch(2, 0.4, &bd);
        assert_eq!(m.samples_done(), 6);
        let total = m.breakdown();
        assert!((total.relu_s - 2.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get_i64("batches").unwrap(), 2);
    }
}
