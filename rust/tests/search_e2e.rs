//! Search engine end-to-end: eco finds an error-free plan, budget search
//! meets its budget with bounded accuracy loss, and the searched plan beats
//! the naive uniform assignment (the paper's §5.4 ablation).
//! Requires artifacts + trained weights (skips cleanly otherwise).

use hummingbird::hummingbird::search::{SearchConfig, SearchEngine, Strategy};
use hummingbird::hummingbird::{simulator, PlanSet};
use hummingbird::model::{Archive, Backend, Dataset, ModelConfig, PlainExecutor};

const MODEL: &str = "micronet_synth10";

struct Env {
    cfg: ModelConfig,
    exec: PlainExecutor,
    dataset: Dataset,
}

fn env() -> Option<Env> {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let root = repo.join("artifacts");
    if !root.join("weights").join(format!("{MODEL}.json")).exists() {
        eprintln!("skipping: weights missing");
        return None;
    }
    let cfg = ModelConfig::load_named(repo, MODEL).ok()?;
    let weights = Archive::load(root.join("weights").join(MODEL)).ok()?;
    let dataset = Dataset::load(&root, &cfg.dataset).ok()?;
    // Naive backend keeps this test independent of the PJRT runtime.
    let exec = PlainExecutor::new(cfg.clone(), weights, Backend::Naive);
    Some(Env { cfg, exec, dataset })
}

fn engine<'a>(e: &'a Env, strategy: Strategy, n: usize) -> SearchEngine<'a> {
    // Default widths / m-scan: later micronet groups carry large
    // activations, so windows must be able to slide up to k ≈ 18.
    let scfg = SearchConfig { strategy, val_samples: n, batch: 64, ..SearchConfig::default() };
    SearchEngine::new(
        &e.exec,
        &e.dataset.val.images,
        &e.dataset.val.labels[..n],
        e.dataset.val.sample_elems,
        scfg,
    )
}

#[test]
fn eco_search_is_error_free_and_shrinks_k() {
    let Some(e) = env() else { return };
    let n = 96;
    let result = engine(&e, Strategy::Eco, n).run().unwrap();
    assert!(
        result.final_acc + 1e-9 >= result.baseline_acc,
        "eco must not lose accuracy: {} vs {}",
        result.final_acc,
        result.baseline_acc
    );
    for g in 0..e.cfg.relu_groups {
        let p = result.plans.plan_for(g);
        assert_eq!(p.m, 0, "eco never drops low bits");
        assert!(p.k < 40, "eco should cut high bits substantially, got k={}", p.k);
        assert!(p.k > 8, "suspiciously small k={}", p.k);
    }
    // Paper: 66-72% of bits discarded by eco (at N=64 and f=16); at f=12
    // with small activations we expect a similar or better fraction.
    let frac = result.plans.budget_fraction(&e.cfg);
    assert!(frac < 0.45, "eco kept {frac} of bits");
}

#[test]
fn budget_search_meets_budget_with_bounded_loss() {
    let Some(e) = env() else { return };
    let n = 96;
    let budget = 8.0 / 64.0;
    let result = engine(&e, Strategy::Budget(budget), n).run().unwrap();
    assert!(
        result.budget_fraction <= budget + 1e-9,
        "budget violated: {} > {budget}",
        result.budget_fraction
    );
    assert!(
        result.final_acc >= result.baseline_acc - 0.10,
        "accuracy collapsed: {} vs baseline {}",
        result.final_acc,
        result.baseline_acc
    );
    assert!(result.evals > 0 && result.search_time_s > 0.0);
}

#[test]
fn searched_plan_beats_naive_uniform() {
    let Some(e) = env() else { return };
    let n = 96;
    let budget = 6.0 / 64.0;
    let result = engine(&e, Strategy::Budget(budget), n).run().unwrap();
    // Naive: same width everywhere, no m tuning (k chosen from low bits).
    let naive = PlanSet::uniform(e.cfg.relu_groups, 6, 0).unwrap();
    let eval = |plans: &PlanSet| {
        simulator::evaluate_plans(
            &e.exec,
            &e.dataset.test.images[..256 * e.dataset.test.sample_elems],
            &e.dataset.test.labels[..256],
            e.dataset.test.sample_elems,
            64,
            plans,
            17,
        )
        .unwrap()
    };
    let searched_acc = eval(&result.plans);
    let naive_acc = eval(&naive);
    // The paper reports >8% gaps; we only require the searched plan to be
    // at least as good (plus slack for evaluation noise).
    assert!(
        searched_acc + 0.02 >= naive_acc,
        "searched {searched_acc} worse than naive {naive_acc}"
    );
}
