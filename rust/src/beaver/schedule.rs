//! Offline provisioning schedules: the exact sequence of dealer draws a
//! protocol run will perform, predicted up front from its shape.
//!
//! The online protocol is deterministic: for a given input shape, plan and
//! party count, every party requests the same correlations in the same
//! order with the same `(w, n_seg, segs)` shapes at every AND round
//! (that determinism is what keeps the per-party dealer streams
//! synchronized in the first place — see the module docs of
//! [`crate::beaver`]). A [`TripleSchedule`] captures that sequence as data,
//! which is what lets the offline phase run ahead of the online one: a
//! [`PrefetchDealer`](super::prefetch::PrefetchDealer) expands the dealer
//! stream in schedule order on a background thread, and the engine's draw
//! calls just swap in the pre-filled buffers.
//!
//! Builders mirror the protocol code they predict (and are pinned against
//! it by the `schedule_predicts_actual_*` tests, which replay real runs
//! through a [`Recorder`]):
//!
//! * [`TripleSchedule::push_ks_add`] mirrors
//!   [`adder::ks_add_with_into`](crate::gmw::adder::ks_add_with_into) with
//!   the default [`AdderOptions`](crate::gmw::adder::AdderOptions)
//!   (batched stage ANDs, last P-update skipped) — the options every
//!   production path uses.
//! * [`TripleSchedule::push_relu`] mirrors
//!   [`GmwParty::relu_into`](crate::gmw::GmwParty::relu_into)
//!   (DReLU's A2B circuit additions + the daBit B2A + the Mult triple).
//! * [`TripleSchedule::for_forward`] dry-runs a model: it walks the ReLU
//!   nodes of a [`ModelConfig`] in execution order with the active
//!   [`PlanSet`] and the serving batch — exactly the draws one
//!   `ShareExecutor::forward` pass performs (linear layers, truncation and
//!   GAP are all communication- and correlation-free).
//!
//! [`TripleSchedule::predicted_usage`] prices a schedule with the same
//! [`TripleUsage`] accounting the dealer keeps, so the offline storage and
//! PRG cost of a provisioning plan are known before a single byte is
//! expanded.

use std::sync::{Arc, Mutex};

use super::{TripleSource, TripleUsage, TtpDealer};
use crate::gmw::{adder, bitsliced, ReluPlan};
use crate::hummingbird::PlanSet;
use crate::model::ModelConfig;

/// One dealer draw, identified by the exact shape the protocol requests.
/// The shape is part of the stream contract: expanding the same ops in the
/// same order yields the same PRG stream assignment as the synchronous
/// dealer, so prefetched material is bit-identical to inline expansion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrawOp {
    /// `n` arithmetic Beaver triples
    /// ([`TtpDealer::arith_triples_into`]).
    Arith { n: usize },
    /// Plane-native binary triples for `segs` segments of `n_seg` w-bit
    /// lanes ([`TtpDealer::bin_triples_planes_into`]).
    BinPlanes { w: u32, n_seg: usize, segs: usize },
    /// `n` daBits ([`TtpDealer::dabits_into`]).
    DaBits { n: usize },
}

impl DrawOp {
    /// (share buffers filled, length of each) — the storage shape of the
    /// op (3 buffers for triples, 2 for daBits).
    pub(crate) fn buf_shape(&self) -> (usize, usize) {
        match *self {
            DrawOp::Arith { n } => (3, n),
            DrawOp::BinPlanes { w, n_seg, segs } => {
                (3, segs * bitsliced::plane_len(n_seg, w))
            }
            DrawOp::DaBits { n } => (2, n),
        }
    }
}

/// An ordered dealer-draw sequence (see the module docs).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TripleSchedule {
    pub ops: Vec<DrawOp>,
}

impl TripleSchedule {
    pub fn new() -> TripleSchedule {
        TripleSchedule::default()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Append the draws of one Kogge–Stone addition over `n` lanes at
    /// width `w` (default `AdderOptions`): the initial AND plus one
    /// batched AND per prefix stage — `(w, n, 2)` segments mid-circuit,
    /// `(w, n, 1)` for the initial AND and the last stage (whose dead
    /// P-update is skipped). `w == 1` is pure XOR: no draws.
    pub fn push_ks_add(&mut self, n: usize, w: u32) {
        if w <= 1 {
            return;
        }
        self.ops.push(DrawOp::BinPlanes { w, n_seg: n, segs: 1 });
        let stages = adder::rounds_for_width(w) - 1;
        for idx in 0..stages {
            let segs = if idx + 1 == stages { 1usize } else { 2 };
            self.ops.push(DrawOp::BinPlanes { w, n_seg: n, segs });
        }
    }

    /// Append an A2B conversion of `n` lanes at width `w`: the PRG
    /// re-sharing is communication- and correlation-free, then each of the
    /// `parties − 1` operand folds is one circuit addition.
    pub fn push_a2b(&mut self, n: usize, w: u32, parties: usize) {
        for _ in 1..parties {
            self.push_ks_add(n, w);
        }
    }

    /// Append a DReLU of `n` elements under `plan` (width ≥ 1): the A2B on
    /// the reduced ring plus the 1-bit B2A's daBits.
    pub fn push_drelu(&mut self, n: usize, plan: ReluPlan, parties: usize) {
        let w = plan.width();
        debug_assert!(w >= 1, "drelu needs at least one bit");
        self.push_a2b(n, w, parties);
        self.ops.push(DrawOp::DaBits { n });
    }

    /// Append a ReLU of `n` elements under `plan`: DReLU plus the Mult
    /// triples. Identity plans (`k == m`) draw nothing.
    pub fn push_relu(&mut self, n: usize, plan: ReluPlan, parties: usize) {
        if plan.is_identity() {
            return;
        }
        self.push_drelu(n, plan, parties);
        self.ops.push(DrawOp::Arith { n });
    }

    /// Schedule for one [`GmwParty::relu`](crate::gmw::GmwParty::relu) of
    /// `n` elements.
    pub fn for_relu(n: usize, plan: ReluPlan, parties: usize) -> TripleSchedule {
        let mut s = TripleSchedule::new();
        s.push_relu(n, plan, parties);
        s
    }

    /// Dry-run one `ShareExecutor::forward` pass of `cfg` under `plans` at
    /// the serving `batch`: every ReLU node in execution order contributes
    /// its per-batch draws (`batch ×` per-sample elements); all other ops
    /// are correlation-free. A serving loop repeats this schedule once per
    /// admitted batch (the batcher always pads to the full artifact
    /// batch), which is what the coordinator's cycling prefetcher exploits.
    pub fn for_forward(
        cfg: &ModelConfig,
        plans: &PlanSet,
        batch: usize,
        parties: usize,
    ) -> TripleSchedule {
        let mut s = TripleSchedule::new();
        for (_node, group, elems) in cfg.relu_elems() {
            s.push_relu(batch * elems, plans.plan_for(group), parties);
        }
        s
    }

    /// Price the schedule with the dealer's own [`TripleUsage`] accounting
    /// (exact, including the per-party PRG draw): what one party will
    /// store and expand for this provisioning plan. Pinned equal to the
    /// actual dealer counters by `predicted_usage_matches_dealer_draw`.
    pub fn predicted_usage(&self, parties: usize) -> TripleUsage {
        debug_assert!(parties >= 2);
        let split = parties as u64 - 1;
        let mut u = TripleUsage::default();
        for op in &self.ops {
            match *op {
                DrawOp::Arith { n } => {
                    u.arith_triples += n as u64;
                    // 2 plaintext draws + 3 splits of (parties − 1) words.
                    u.prg_words += n as u64 * (2 + 3 * split);
                }
                DrawOp::BinPlanes { w, n_seg, segs } => {
                    let pl = (segs * bitsliced::plane_len(n_seg, w)) as u64;
                    u.bin_plane_words += pl;
                    u.bin_triple_lanes += (segs * n_seg) as u64;
                    u.prg_words += pl * (2 + 3 * split);
                }
                DrawOp::DaBits { n } => {
                    u.dabits += n as u64;
                    // 1 plaintext bit + a binary and an arithmetic split.
                    u.prg_words += n as u64 * (1 + 2 * split);
                }
            }
        }
        u
    }
}

/// Diagnostic [`TripleSource`] that logs every draw's [`DrawOp`] while
/// delegating to an inner [`TtpDealer`] — the "recording dry run" used to
/// pin schedule prediction against the protocol's actual draws. The log is
/// shared out through an `Arc<Mutex<_>>` because the source itself is
/// boxed into the engine (`GmwParty::set_triple_source`).
pub struct Recorder {
    inner: TtpDealer,
    log: Arc<Mutex<Vec<DrawOp>>>,
}

impl Recorder {
    /// Wrap `inner`; returns the recorder and a handle to its draw log.
    #[allow(clippy::type_complexity)]
    pub fn new(inner: TtpDealer) -> (Recorder, Arc<Mutex<Vec<DrawOp>>>) {
        let log = Arc::new(Mutex::new(Vec::new()));
        (Recorder { inner, log: Arc::clone(&log) }, log)
    }

    /// Lock the draw log, recovering from poisoning (the log is
    /// append-only and stays consistent if a holder panicked).
    fn lock_log(&self) -> std::sync::MutexGuard<'_, Vec<DrawOp>> {
        self.log.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl TripleSource for Recorder {
    fn arith_triples_into(
        &mut self,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> crate::error::Result<()> {
        self.lock_log().push(DrawOp::Arith { n: a.len() });
        self.inner.arith_triples_into(a, b, c);
        Ok(())
    }

    fn bin_triples_planes_into(
        &mut self,
        w: u32,
        n_seg: usize,
        segs: usize,
        a: &mut [u64],
        b: &mut [u64],
        c: &mut [u64],
    ) -> crate::error::Result<()> {
        self.lock_log().push(DrawOp::BinPlanes { w, n_seg, segs });
        self.inner.bin_triples_planes_into(w, n_seg, segs, a, b, c);
        Ok(())
    }

    fn dabits_into(
        &mut self,
        r_bin: &mut [u64],
        r_arith: &mut [u64],
    ) -> crate::error::Result<()> {
        self.lock_log().push(DrawOp::DaBits { n: r_bin.len() });
        self.inner.dabits_into(r_bin, r_arith);
        Ok(())
    }

    fn usage(&self) -> TripleUsage {
        self.inner.usage()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Draw a schedule synchronously on a dealer (test helper).
    fn draw_all(dealer: &mut TtpDealer, schedule: &TripleSchedule) {
        for op in &schedule.ops {
            let (bufs, len) = op.buf_shape();
            let mut a = vec![0u64; len];
            let mut b = vec![0u64; len];
            let mut c = vec![0u64; len];
            match *op {
                DrawOp::Arith { .. } => dealer.arith_triples_into(&mut a, &mut b, &mut c),
                DrawOp::BinPlanes { w, n_seg, segs } => {
                    dealer.bin_triples_planes_into(w, n_seg, segs, &mut a, &mut b, &mut c)
                }
                DrawOp::DaBits { .. } => {
                    debug_assert_eq!(bufs, 2);
                    dealer.dabits_into(&mut a, &mut b)
                }
            }
        }
    }

    /// ks_add schedules mirror the adder's round structure: one draw per
    /// communication round, `(n, 2)` segments mid-circuit, `(n, 1)` at the
    /// boundary rounds, nothing at w = 1.
    #[test]
    fn ks_add_schedule_matches_round_structure() {
        for w in [1u32, 2, 3, 6, 8, 13, 64] {
            let n = 100usize;
            let mut s = TripleSchedule::new();
            s.push_ks_add(n, w);
            assert_eq!(s.len() as u32, adder::rounds_for_width(w), "w={w}");
            if w > 1 {
                assert_eq!(s.ops[0], DrawOp::BinPlanes { w, n_seg: n, segs: 1 });
                assert_eq!(*s.ops.last().unwrap(), DrawOp::BinPlanes { w, n_seg: n, segs: 1 });
                for op in &s.ops[1..s.len() - 1] {
                    assert_eq!(*op, DrawOp::BinPlanes { w, n_seg: n, segs: 2 }, "w={w}");
                }
            }
        }
    }

    #[test]
    fn relu_schedule_composition() {
        let n = 64usize;
        let plan = ReluPlan::new(12, 4).unwrap(); // w = 8: 4 add rounds
        for parties in [2usize, 3] {
            let s = TripleSchedule::for_relu(n, plan, parties);
            // (parties−1) adds × rounds_for_width(8) + daBits + arith.
            let adds = (parties - 1) * adder::rounds_for_width(8) as usize;
            assert_eq!(s.len(), adds + 2, "parties={parties}");
            assert_eq!(s.ops[adds], DrawOp::DaBits { n });
            assert_eq!(s.ops[adds + 1], DrawOp::Arith { n });
        }
        // Identity plans draw nothing; w=1 plans skip the adder entirely.
        assert!(TripleSchedule::for_relu(n, ReluPlan::new(5, 5).unwrap(), 2).is_empty());
        let w1 = TripleSchedule::for_relu(n, ReluPlan::new(8, 7).unwrap(), 2);
        assert_eq!(w1.ops, vec![DrawOp::DaBits { n }, DrawOp::Arith { n }]);
    }

    /// The priced provisioning plan equals the dealer's own accounting
    /// after actually drawing the schedule — including the exact PRG word
    /// count, for several party counts.
    #[test]
    fn predicted_usage_matches_dealer_draw() {
        for parties in [2usize, 3, 4] {
            for plan in [ReluPlan::new(12, 4).unwrap(), ReluPlan::new(8, 7).unwrap()] {
                let s = TripleSchedule::for_relu(321, plan, parties);
                let mut d = TtpDealer::new(9, parties - 1, parties);
                draw_all(&mut d, &s);
                assert_eq!(d.usage(), s.predicted_usage(parties), "parties={parties}");
            }
        }
    }

    /// The recorder's log is the schedule (dealer-level check; the
    /// protocol-level pin lives in `tests/prefetch.rs`).
    #[test]
    fn recorder_logs_draws_in_order() {
        let (mut rec, log) = Recorder::new(TtpDealer::new(5, 0, 2));
        let mut a = vec![0u64; 10];
        let mut b = vec![0u64; 10];
        let mut c = vec![0u64; 10];
        rec.arith_triples_into(&mut a, &mut b, &mut c);
        let mut r_bin = vec![0u64; 5];
        let mut r_arith = vec![0u64; 5];
        rec.dabits_into(&mut r_bin, &mut r_arith);
        assert_eq!(*log.lock().unwrap(), vec![DrawOp::Arith { n: 10 }, DrawOp::DaBits { n: 5 }]);
        // Delegation is stream-exact: a fresh sync dealer drawing the same
        // ops lands on the same stream position.
        let mut d = TtpDealer::new(5, 0, 2);
        d.arith_triples(10);
        d.dabits(5);
        assert_eq!(rec.usage(), d.usage());
    }
}
