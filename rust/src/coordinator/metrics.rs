//! Serving metrics: request latency, throughput, communication, the
//! compute/communication breakdown used by Figs 1 & 10, and the fault
//! counters of the degradation path (DESIGN.md §7).

use std::sync::Mutex;
use std::time::Instant;

use crate::model::ExecBreakdown;
use crate::util::json::Json;
use crate::util::stats;

/// Accumulated serving metrics (thread-safe).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    request_latencies_s: Vec<f64>,
    batch_sizes: Vec<usize>,
    samples_done: u64,
    batches_done: u64,
    breakdown: ExecBreakdown,
    started: Option<Instant>,
    finished: Option<Instant>,
    faults: FaultCounters,
}

/// Failure counters of the graceful-degradation path (DESIGN.md §7): a
/// faulted session fails its in-flight batch — counted here — while the
/// coordinator respawns the party session and keeps serving.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct FaultCounters {
    /// Batches that answered their requests with an error because a party
    /// session faulted mid-flight. One failed batch = one increment,
    /// regardless of batch size.
    pub failed_jobs: u64,
    /// Failed batches whose root cause was a deadline expiry
    /// (`Error::Timeout`) — a hung peer, as opposed to a crash.
    pub timeouts: u64,
    /// Transport-level retry attempts absorbed without failing a job
    /// (from `NetStats` on deployments that report them).
    pub retries: u64,
    /// Transport-level reconnects absorbed without failing a job.
    pub reconnects: u64,
    /// Times the coordinator tore down a faulted party session and
    /// spawned a fresh one.
    pub sessions_restarted: u64,
}

/// Point-in-time view of the counters, for assertions and dashboards.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    pub samples_done: u64,
    pub batches_done: u64,
    pub faults: FaultCounters,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lock the accumulator, recovering from poisoning: metrics must stay
    /// readable even if a thread panicked mid-update (counters are plain
    /// integers/vectors and stay consistent).
    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn mark_start(&self) {
        let mut m = self.lock();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
    }

    pub fn record_batch(&self, batch: usize, latency_s: f64, bd: &ExecBreakdown) {
        let mut m = self.lock();
        m.batch_sizes.push(batch);
        m.samples_done += batch as u64;
        m.batches_done += 1;
        m.breakdown.add(bd);
        m.finished = Some(Instant::now());
        for _ in 0..batch {
            m.request_latencies_s.push(latency_s);
        }
    }

    /// A batch failed: a party session faulted and its requests were
    /// answered with an error. `was_timeout` marks a deadline-expiry root
    /// cause (vs. a crash/link fault).
    pub fn record_failed_job(&self, was_timeout: bool) {
        let mut m = self.lock();
        m.faults.failed_jobs += 1;
        if was_timeout {
            m.faults.timeouts += 1;
        }
    }

    /// The coordinator replaced a faulted party session with a fresh one.
    pub fn record_session_restart(&self) {
        self.lock().faults.sessions_restarted += 1;
    }

    /// Fold in transport-level recovery counters (retries/reconnects that
    /// were absorbed without failing a job).
    pub fn record_net_recovery(&self, retries: u64, reconnects: u64) {
        let mut m = self.lock();
        m.faults.retries += retries;
        m.faults.reconnects += reconnects;
    }

    /// Assertable point-in-time counters (the chaos suite pins these).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.lock();
        MetricsSnapshot {
            samples_done: m.samples_done,
            batches_done: m.batches_done,
            faults: m.faults,
        }
    }

    pub fn samples_done(&self) -> u64 {
        self.lock().samples_done
    }

    /// Wall-clock between first and last batch.
    pub fn wall_seconds(&self) -> f64 {
        let m = self.lock();
        match (m.started, m.finished) {
            (Some(s), Some(f)) => f.duration_since(s).as_secs_f64(),
            _ => 0.0,
        }
    }

    pub fn throughput(&self) -> f64 {
        let w = self.wall_seconds();
        if w <= 0.0 {
            0.0
        } else {
            self.samples_done() as f64 / w
        }
    }

    pub fn breakdown(&self) -> ExecBreakdown {
        self.lock().breakdown
    }

    pub fn to_json(&self) -> Json {
        let m = self.lock();
        Json::obj(vec![
            ("samples", Json::Int(m.samples_done as i64)),
            ("batches", Json::Int(m.batches_done as i64)),
            ("p50_latency_s", Json::Num(stats::median(&m.request_latencies_s))),
            ("p95_latency_s", Json::Num(stats::percentile(&m.request_latencies_s, 95.0))),
            ("linear_s", Json::Num(m.breakdown.linear_s)),
            ("relu_s", Json::Num(m.breakdown.relu_s)),
            ("other_s", Json::Num(m.breakdown.other_s)),
            ("failed_jobs", Json::Int(m.faults.failed_jobs as i64)),
            ("timeouts", Json::Int(m.faults.timeouts as i64)),
            ("retries", Json::Int(m.faults.retries as i64)),
            ("reconnects", Json::Int(m.faults.reconnects as i64)),
            ("sessions_restarted", Json::Int(m.faults.sessions_restarted as i64)),
        ])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let m = Metrics::new();
        m.mark_start();
        let bd = ExecBreakdown { linear_s: 0.5, relu_s: 1.0, other_s: 0.1 };
        m.record_batch(4, 0.2, &bd);
        m.record_batch(2, 0.4, &bd);
        assert_eq!(m.samples_done(), 6);
        let total = m.breakdown();
        assert!((total.relu_s - 2.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get_i64("batches").unwrap(), 2);
    }

    /// The fault counters are independent of the throughput counters and
    /// show up in both the snapshot and the JSON export.
    #[test]
    fn fault_counters_snapshot() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().faults, FaultCounters::default());
        m.record_failed_job(false);
        m.record_failed_job(true);
        m.record_session_restart();
        m.record_net_recovery(3, 1);
        let s = m.snapshot();
        assert_eq!(s.faults.failed_jobs, 2);
        assert_eq!(s.faults.timeouts, 1);
        assert_eq!(s.faults.retries, 3);
        assert_eq!(s.faults.reconnects, 1);
        assert_eq!(s.faults.sessions_restarted, 1);
        assert_eq!(s.samples_done, 0, "failures must not count as served samples");
        let j = m.to_json();
        assert_eq!(j.get_i64("failed_jobs").unwrap(), 2);
        assert_eq!(j.get_i64("sessions_restarted").unwrap(), 1);
    }
}
