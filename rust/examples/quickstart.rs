//! Quickstart: the core HummingBird mechanism in ~60 lines.
//!
//! Two simulated parties evaluate ReLU over secret shares three ways —
//! exact CrypTen-style baseline (64-bit ring), HummingBird-eco (high bits
//! dropped, error-free), and an aggressive HummingBird window — and print
//! the accuracy/communication trade-off.
//!
//! Run: `cargo run --release --example quickstart`

use hummingbird::crypto::prg::Prg;
use hummingbird::gmw::harness::run_parties;
use hummingbird::gmw::ReluPlan;
use hummingbird::sharing::{reconstruct_arith, share_arith};
use hummingbird::util::stats;

fn main() {
    // Secret inputs: fixed-point-ish values in [-8, 8) at scale 2^12.
    let fx = hummingbird::ring::FixedPoint::new(12);
    let mut prg = Prg::from_entropy();
    let n = 4096;
    let x_f: Vec<f64> = (0..n).map(|_| (prg.next_f64() - 0.5) * 16.0).collect();
    let x: Vec<u64> = x_f.iter().map(|v| fx.encode(*v)).collect();

    // The client splits x into two arithmetic shares; each party sees only
    // uniform-random garbage.
    let shares = share_arith(&mut prg, &x, 2);

    println!("ReLU over 2-party GMW, {n} elements, fixed-point f=12\n");
    println!(
        "{:<34} {:>10} {:>7} {:>12} {:>9}",
        "plan", "bytes", "rounds", "mean |err|", "pruned"
    );
    for (name, plan) in [
        ("baseline: full 64-bit ring", ReluPlan::BASELINE),
        ("eco: bits [0,17) — error-free", ReluPlan::new(17, 0).unwrap()),
        ("hummingbird: bits [8,16)", ReluPlan::new(16, 8).unwrap()),
        ("hummingbird: bits [10,16)", ReluPlan::new(16, 10).unwrap()),
    ] {
        let shares = shares.clone();
        let run = run_parties(2, 42, move |party| {
            let me = party.party();
            party.relu(&shares[me], plan).unwrap()
        });
        let out = reconstruct_arith(&run.outputs);
        let mut abs_err = 0.0;
        let mut pruned = 0usize;
        for (xf, o) in x_f.iter().zip(&out) {
            let expect = xf.max(0.0);
            let got = fx.decode(*o);
            abs_err += (got - expect).abs();
            if expect > 0.0 && got == 0.0 {
                pruned += 1;
            }
        }
        println!(
            "{:<34} {:>10} {:>7} {:>12.6} {:>8}",
            name,
            stats::fmt_bytes(run.trace.total_bytes()),
            run.trace.total_rounds(),
            abs_err / n as f64,
            pruned
        );
    }
    println!(
        "\nThe reduced-ring plans communicate a fraction of the baseline; the\n\
         eco window is exact (Theorem 1) while m>0 additionally prunes small\n\
         activations (Theorem 2) — the paper's accuracy/performance dial."
    );
}
