//! Data-parallel helpers on a **persistent worker pool** (rayon is not
//! available offline).
//!
//! PR 1 built these on `std::thread::scope`, which pays `threads - 1` OS
//! thread spawns per parallel region — a fixed multi-microsecond tax on
//! every GMW round. The pool here is spawned once (lazily, on the first
//! parallel region) and parked between regions: a region enqueues its
//! chunks on a shared `std::sync::mpsc` channel, workers drain them, and a
//! condvar latch releases the caller when the last chunk lands. No
//! crossbeam, no allocation per region beyond the channel nodes.
//!
//! These helpers back the GMW hot path: [`par_chunks_mut`] drives the
//! buffer-writing kernels and the fused bitpack/unpack (`gmw::kernels`,
//! `bitpack`), while [`par_chunks`] remains the generic index-range
//! splitter. All of them produce results identical to the single-threaded
//! loop for any thread count — the protocol depends on that for
//! bit-exactness. The chunk decomposition is a pure function of
//! `(n, threads)` and each index is written by exactly one chunk, so the
//! number of *actual* pool workers (or which worker runs which chunk)
//! can never change results.
//!
//! # Safety model
//!
//! A region hands workers a borrowed closure through a lifetime-erased
//! trait-object reference (the rayon trick). This is sound because the
//! caller **blocks on the region's latch** before returning: the closure
//! and the region header outlive every access from worker threads. A
//! panic inside a chunk is caught on the worker (so the latch still
//! releases and the worker survives for future regions) and re-thrown on
//! the caller's thread.
//!
//! Workers never run nested regions: a `par_*` call from a pool worker
//! degrades to the inline sequential loop (same results), so a region can
//! never deadlock waiting on workers occupied by its own chunks.
//!
//! # Verification (DESIGN.md §8)
//!
//! The safety argument above is checked three ways: `hblint` enforces the
//! `SAFETY:` comment discipline on every `unsafe` site in this file; the
//! Miri CI job interprets the pool-driving unit tests (set
//! `HB_POOL_WORKERS` to bound the worker count under the interpreter); and
//! under `RUSTFLAGS="--cfg loom"` the [`Region`] latch compiles against
//! loom's checked sync primitives and the `loom_models` tests drive its
//! lifecycle directly (delegation itself is compile-time disabled under
//! loom — the persistent OS pool is outside loom's model, so `par_*` run
//! inline and the models exercise `Region` the way `run_delegated` does).

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex as StdMutex, OnceLock};

// The region latch (and only the latch) swaps its sync primitives for
// loom's checked twins under `--cfg loom`; the pool machinery itself stays
// on std (persistent workers are never engaged under loom — see the
// module docs).
#[cfg(loom)]
use loom::sync::{atomic::AtomicUsize, Condvar, Mutex};
#[cfg(not(loom))]
use std::sync::{atomic::AtomicUsize, Condvar, Mutex};

/// Number of worker threads to use for data-parallel loops.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

// ---------------------------------------------------------------------------
// Pool internals.
// ---------------------------------------------------------------------------

/// One unit of work: run chunk `t` of the region behind `region`.
struct Chunk {
    /// Pointer to a `Region` on the issuing caller's stack. Valid for the
    /// whole execution of the chunk: the caller blocks on the region latch
    /// until every chunk has finished.
    region: *const Region,
    t: usize,
}

// SAFETY: the raw pointer targets a `Region` that the issuing thread keeps
// alive (blocked on the latch) until all chunks complete, so the worker's
// access stays within the pointee's lifetime; the shared access itself is
// sound because `Region` is `Sync` (atomics, mutex/condvar and a `Sync`
// closure ref — pinned by `send_ptr_bounds_are_enforced` in the tests so
// a non-`Sync` field can never sneak in silently).
unsafe impl Send for Chunk {}

/// Per-region header: the erased closure plus a completion latch.
struct Region {
    /// Lifetime-erased reference to the caller's chunk closure. Only
    /// dereferenced while the caller is parked on `wait()`.
    func: &'static (dyn Fn(usize) + Sync),
    remaining: AtomicUsize,
    /// First delegated chunk's panic payload, re-thrown on the caller so
    /// the original assertion message survives.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    done: Mutex<bool>,
    cv: Condvar,
}

impl Region {
    fn finish_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = self.done.lock().unwrap_or_else(|p| p.into_inner());
            *done = true;
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut done = self.done.lock().unwrap_or_else(|p| p.into_inner());
        while !*done {
            done = self.cv.wait(done).unwrap_or_else(|p| p.into_inner());
        }
    }
}

struct Pool {
    tx: StdMutex<mpsc::Sender<Chunk>>,
}

static POOL: OnceLock<Pool> = OnceLock::new();
/// Monotonic count of worker threads ever spawned (pinned by the reuse
/// test: it must not grow once the pool exists). Deliberately a std
/// atomic even under `--cfg loom`: loom types cannot live in statics.
static SPAWNED: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
/// Monotonic count of delegated regions executed on the pool.
static REGIONS: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

thread_local! {
    /// True on pool worker threads; guards against nested regions.
    static IN_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

fn worker_main(rx: Arc<StdMutex<mpsc::Receiver<Chunk>>>) {
    IN_WORKER.with(|w| w.set(true));
    loop {
        // Hold the receiver lock only while pulling one chunk; blocking in
        // recv() under the lock is the standard shared-mpsc worker pattern
        // (dispatch serializes, execution does not).
        let chunk = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            match guard.recv() {
                Ok(c) => c,
                Err(_) => return, // pool dropped (process exit)
            }
        };
        // SAFETY: the issuing caller blocks on the latch until finish_one
        // below, so the region (and the closure it references) is alive.
        let region = unsafe { &*chunk.region };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            (region.func)(chunk.t)
        }));
        if let Err(payload) = result {
            let mut slot = region.panic_payload.lock().unwrap_or_else(|p| p.into_inner());
            slot.get_or_insert(payload);
        }
        region.finish_one();
    }
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let (tx, rx) = mpsc::channel::<Chunk>();
        let rx = Arc::new(StdMutex::new(rx));
        // One worker per core: regions also run their first chunk on the
        // calling thread, so this slightly oversubscribes under concurrent
        // callers — harmless (parked workers cost nothing) and it keeps
        // single-caller regions fully parallel. `HB_POOL_WORKERS` bounds
        // the pool explicitly — the Miri/TSan CI jobs set it to 2 so the
        // interpreted/instrumented runs do not spawn one thread per host
        // core.
        let workers = std::env::var("HB_POOL_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&w| w >= 1)
            .unwrap_or_else(default_threads);
        for i in 0..workers {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("hb-pool-{i}"))
                .spawn(move || worker_main(rx))
                // LINT-ALLOW: unwrap — OS thread-spawn failure at pool init is
                // unrecoverable resource exhaustion; dying loudly is correct.
                .expect("spawn pool worker");
            SPAWNED.fetch_add(1, Ordering::Relaxed);
        }
        Pool { tx: StdMutex::new(tx) }
    })
}

/// Number of persistent pool workers ever spawned (0 until the first
/// parallel region initializes the pool; constant afterwards).
pub fn pool_workers_spawned() -> usize {
    SPAWNED.load(Ordering::Relaxed)
}

/// Number of delegated parallel regions executed so far.
pub fn pool_regions_run() -> usize {
    REGIONS.load(Ordering::Relaxed)
}

/// Run `g(t)` for every `t` in `delegated` on pool workers while the
/// caller runs `inline()` (chunk 0) on its own thread; returns after all
/// chunks complete. Re-throws any chunk panic on the caller's thread.
fn run_delegated(
    delegated: std::ops::Range<usize>,
    g: &(dyn Fn(usize) + Sync),
    inline: impl FnOnce(),
) {
    debug_assert!(!delegated.is_empty());
    // SAFETY: lifetime erasure only — the region (and thus every worker
    // access to `g`) is confined to this call: we block on the latch
    // before returning, so `g` strictly outlives all uses.
    let func: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(g) };
    let region = Region {
        func,
        remaining: AtomicUsize::new(delegated.len()),
        panic_payload: Mutex::new(None),
        done: Mutex::new(false),
        cv: Condvar::new(),
    };
    let pool = pool();
    {
        let tx = pool.tx.lock().unwrap_or_else(|p| p.into_inner());
        for t in delegated {
            // LINT-ALLOW: unwrap — send fails only if every worker exited,
            // impossible while POOL lives; failing beats hanging the latch.
            tx.send(Chunk { region: &region, t }).expect("worker pool alive");
        }
    }
    REGIONS.fetch_add(1, Ordering::Relaxed);
    // Run the caller's chunk, but never unwind past the latch: workers
    // hold pointers into this stack frame until every chunk completes.
    let inline_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(inline));
    region.wait();
    if let Err(payload) = inline_result {
        std::panic::resume_unwind(payload);
    }
    let delegated_panic =
        region.panic_payload.lock().unwrap_or_else(|p| p.into_inner()).take();
    if let Some(payload) = delegated_panic {
        std::panic::resume_unwind(payload);
    }
}

// ---------------------------------------------------------------------------
// Public API (unchanged from the scoped-thread version).
// ---------------------------------------------------------------------------

/// Run `f(chunk_index, item_range)` over `n` items split into contiguous
/// chunks across up to `threads` workers. `f` must be `Send + Sync`.
///
/// Returns after all chunks complete. With `threads <= 1` or tiny `n` this
/// runs inline on the caller's thread; otherwise chunk 0 runs on the
/// caller and chunks 1.. on the persistent pool (`threads` workers cost
/// `threads - 1` chunk handoffs and zero thread spawns).
pub fn par_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Send + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    // Under loom, delegation is disabled at compile time: the persistent
    // OS pool is outside loom's model, and the inline loop is the
    // bit-identical fallback the nested-region path already relies on.
    if threads == 1 || n < 2 || in_worker() || cfg!(loom) {
        f(0, 0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    let nchunks = n.div_ceil(chunk);
    if nchunks <= 1 {
        f(0, 0..n);
        return;
    }
    let g = |t: usize| f(t, t * chunk..((t + 1) * chunk).min(n));
    run_delegated(1..nchunks, &g, || g(0));
}

/// Split `data` into contiguous chunks and run `f(offset, chunk)` on up to
/// `threads` workers. Safe (no aliasing): each chunk is a disjoint `&mut`
/// sub-slice reconstructed from a base pointer at word-disjoint offsets.
/// `offset` is the index of the chunk's first element in `data`, so `f`
/// can read companion input slices at the matching positions.
///
/// This is the write-side workhorse of the zero-allocation GMW hot path:
/// kernels and the fused bitpack use it to fill caller-provided buffers in
/// parallel without any per-call allocation or thread spawn.
pub fn par_chunks_mut<T, F>(data: &mut [T], threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    let n = data.len();
    let threads = threads.max(1).min(n.max(1));
    // See par_chunks: loom builds always take the inline path.
    if threads == 1 || n < 2 || in_worker() || cfg!(loom) {
        f(0, data);
        return;
    }
    let chunk = n.div_ceil(threads);
    let nchunks = n.div_ceil(chunk);
    if nchunks <= 1 {
        f(0, data);
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    let g = move |t: usize| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        // SAFETY: chunks are pairwise-disjoint index ranges of `data`,
        // each handed to exactly one worker, and `data` outlives the
        // region (the caller blocks until all chunks complete).
        let slice = unsafe { std::slice::from_raw_parts_mut(base.get().add(lo), hi - lo) };
        f(lo, slice);
    };
    run_delegated(1..nchunks, &g, || g(0));
}

/// Map `f` over `items` in parallel, preserving order.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send + Default + Clone,
    F: Fn(&T) -> U + Send + Sync,
{
    let mut out = vec![U::default(); items.len()];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        let out_ref = &out_ptr;
        par_chunks(items.len(), threads, move |_, range| {
            for i in range {
                // SAFETY: each index is written by exactly one chunk.
                unsafe { *out_ref.get().add(i) = f(&items[i]) };
            }
        });
    }
    out
}

/// Wrapper to allow sharing a raw pointer across pool threads when the
/// access pattern is provably disjoint (each index written by exactly one
/// chunk). Used by [`par_map`], [`par_chunks_mut`] and by `bitpack`'s
/// parallel word packer, where output regions are word-disjoint but not
/// representable as `&mut` sub-slices of equal element type. Deliberately
/// `pub(crate)`: the `Send`/`Sync` impls launder the disjointness
/// obligation, so the contract must stay auditable within this crate.
///
/// # Why the `T: Send` bounds are required
///
/// Before PR 7 the impls below were **unconditional** — a soundness hole:
/// `SendPtr<Rc<u64>>` was `Send + Sync`, so a closure moving one into
/// [`par_map`]'s workers would have compiled and raced the non-atomic
/// `Rc` refcount across threads. With the bounds, `SendPtr<T>` crossing a
/// thread boundary requires `T: Send` and such code is rejected at the
/// type level:
///
/// ```text
/// fn assert_send<T: Send>() {}
/// assert_send::<SendPtr<std::rc::Rc<u64>>>(); // does not compile
/// ```
///
/// `T: Sync` is deliberately **not** required: a `SendPtr` only ever
/// confers *exclusive* access to disjoint slots — it behaves like a family
/// of `&mut T`, one per chunk, never a shared `&T`. `&mut T` crosses
/// threads iff `T: Send`, and that is exactly the bound both impls carry
/// (the positive direction is pinned by `send_ptr_bounds_are_enforced` in
/// the tests).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

impl<T> SendPtr<T> {
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

// SAFETY: moving a `SendPtr<T>` to another thread hands that thread the
// ability to write `T` values into the pointee, which is exactly what
// `T: Send` licenses; callers guarantee each slot is written by exactly
// one chunk (documented above).
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: a shared `&SendPtr<T>` yields the raw pointer for *disjoint*
// writes only — semantically a `&mut T` per chunk, never a shared `&T` —
// so `T: Send` (not `T: Sync`) is the required bound; see the doc comment
// for why the previously unconditional impl was unsound.
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices_exactly_once() {
        let n = 1037;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n, 4, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..501).collect();
        let out = par_map(&items, 3, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn degenerate_sizes() {
        par_chunks(0, 4, |_, r| assert!(r.is_empty()));
        let out = par_map::<usize, usize, _>(&[], 4, |x| *x);
        assert!(out.is_empty());
        let out = par_map(&[7usize], 4, |x| x + 1);
        assert_eq!(out, vec![8]);
    }

    /// Hot-path contract: for every thread count the helpers must produce
    /// output identical to the single-threaded reference loop. This is what
    /// the GMW kernels and the fused bitpack rely on for bit-exactness.
    #[test]
    fn par_chunks_matches_single_threaded_reference() {
        for n in [0usize, 1, 2, 3, 1000, 1037] {
            let input: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(0x9e37_79b9)).collect();
            let reference: Vec<u64> =
                input.iter().enumerate().map(|(i, v)| v ^ (i as u64)).collect();
            for threads in [1usize, 2, default_threads()] {
                let out: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
                par_chunks(n, threads, |_, range| {
                    for i in range {
                        out[i].store((input[i] ^ (i as u64)) as usize, Ordering::Relaxed);
                    }
                });
                let got: Vec<u64> =
                    out.iter().map(|a| a.load(Ordering::Relaxed) as u64).collect();
                assert_eq!(got, reference, "n={n} threads={threads}");
            }
        }
    }

    /// The `SendPtr` impls must keep their `T: Send` bounds (see the
    /// type's docs for the soundness argument) and `Region` must stay
    /// `Sync` — the obligation `Chunk`'s `unsafe impl Send` discharges.
    #[test]
    fn send_ptr_bounds_are_enforced() {
        fn assert_send<T: Send>() {}
        fn assert_sync<T: Sync>() {}
        assert_send::<SendPtr<u8>>();
        assert_sync::<SendPtr<u8>>();
        assert_send::<SendPtr<u64>>();
        assert_sync::<SendPtr<u64>>();
        assert_sync::<Region>();
        // The negative direction (`SendPtr<Rc<u64>>: !Send`) is a
        // compile-time fact documented on `SendPtr`; it cannot be asserted
        // at runtime without a compile-fail harness.
    }

    /// Miri-sized variant of the reference-equivalence sweep (DESIGN.md
    /// §8): small enough for the interpreter while still crossing the
    /// delegated `SendPtr` write path (threads >= 2).
    #[test]
    fn par_chunks_mut_matches_reference_miri_sized() {
        let n = 97usize;
        let input: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(31)).collect();
        let reference: Vec<u64> = input.iter().map(|v| v.wrapping_add(7)).collect();
        let mut out = vec![0u64; n];
        par_chunks_mut(&mut out, 2, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = input[off + i].wrapping_add(7);
            }
        });
        assert_eq!(out, reference);
    }

    #[cfg_attr(miri, ignore = "4099-element × thread-count sweep is too slow interpreted")]
    #[test]
    fn par_chunks_mut_matches_reference_all_thread_counts() {
        for n in [0usize, 1, 5, 1024, 4099] {
            let input: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(31)).collect();
            let reference: Vec<u64> = input.iter().map(|v| v.wrapping_add(7)).collect();
            for threads in [1usize, 2, 3, default_threads()] {
                let mut out = vec![0u64; n];
                par_chunks_mut(&mut out, threads, |off, chunk| {
                    for (i, o) in chunk.iter_mut().enumerate() {
                        *o = input[off + i].wrapping_add(7);
                    }
                });
                assert_eq!(out, reference, "n={n} threads={threads}");
            }
        }
    }

    /// `n < threads` must neither panic nor drop elements.
    #[test]
    fn more_threads_than_items() {
        let n = 3;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n, 16, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));

        let mut out = vec![0u8; 2];
        par_chunks_mut(&mut out, 64, |off, chunk| {
            for (i, o) in chunk.iter_mut().enumerate() {
                *o = (off + i) as u8 + 1;
            }
        });
        assert_eq!(out, vec![1, 2]);
    }

    /// The persistence claim, pinned: once the pool exists, running many
    /// more parallel regions spawns **zero** new threads (workers are
    /// parked and reused), and every region still produces the
    /// single-threaded reference result.
    #[cfg_attr(miri, ignore = "multi-region 4096-element sweep is too slow interpreted")]
    #[test]
    fn pool_workers_are_reused_across_regions() {
        let n = 4096usize;
        let input: Vec<u64> = (0..n as u64).map(|i| i.wrapping_mul(0xdead_beef)).collect();
        let reference: Vec<u64> = input.iter().map(|v| v.rotate_left(9) ^ 0x55).collect();
        let run_region = |threads: usize| {
            let mut out = vec![0u64; n];
            par_chunks_mut(&mut out, threads, |off, chunk| {
                for (i, o) in chunk.iter_mut().enumerate() {
                    *o = input[off + i].rotate_left(9) ^ 0x55;
                }
            });
            out
        };
        // Force pool creation with one region.
        assert_eq!(run_region(2), reference);
        let spawned = pool_workers_spawned();
        assert!(spawned >= 1, "pool must have spawned workers");
        let regions_before = pool_regions_run();
        // >= 3 further regions at mixed thread counts: identical results,
        // no new threads.
        for (round, threads) in [2usize, 3, default_threads().max(2), 2].iter().enumerate() {
            assert_eq!(run_region(*threads), reference, "round {round}");
            assert_eq!(
                pool_workers_spawned(),
                spawned,
                "region {round} spawned new threads instead of reusing the pool"
            );
        }
        assert!(
            pool_regions_run() >= regions_before + 4,
            "regions must have executed on the pool"
        );
    }

    /// Nested parallelism from inside a worker degrades to the sequential
    /// loop (same results) instead of deadlocking the pool.
    #[test]
    fn nested_region_runs_inline_without_deadlock() {
        let n = 64usize;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(n, 4, |_, range| {
            for i in range {
                // A nested region per outer index: must complete inline.
                par_chunks(8, 4, |_, inner| {
                    for _ in inner {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 8));
    }

    /// A panic in a delegated chunk propagates to the caller **with its
    /// original payload**, and the pool survives for later regions.
    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let result = std::panic::catch_unwind(|| {
            par_chunks(1024, 4, |t, _range| {
                if t == 2 {
                    panic!("boom");
                }
            });
        });
        let payload = result.expect_err("chunk panic must propagate to the caller");
        assert_eq!(
            payload.downcast_ref::<&str>(),
            Some(&"boom"),
            "original panic payload must survive the pool hop"
        );
        // Pool still works.
        let hits: Vec<AtomicUsize> = (0..256).map(|_| AtomicUsize::new(0)).collect();
        par_chunks(256, 4, |_, range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}

// Loom interleaving models (DESIGN.md §8): compiled only under
// `RUSTFLAGS="--cfg loom"`, run with `cargo test --lib -- loom_models`.
// Against the vendored offline shim (rust/vendor/loom) each model runs
// once as a deterministic concurrency smoke test; against the real crate
// the identical code exhaustively explores the latch's interleavings.
// The models drive `Region` exactly the way `run_delegated` does — raw
// `Chunk` pointers into the caller's frame, caller parked on the latch —
// so the production safety argument is what gets checked.
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use loom::thread;

    /// Caller runs chunk 0 inline, model "workers" run chunks 1..3 through
    /// raw `Chunk` pointers; after `wait()` returns, every chunk's write
    /// must be visible on the caller's thread with no extra
    /// synchronization — the happens-before edge the whole pool rests on.
    #[test]
    fn region_latch_publishes_all_chunk_writes() {
        loom::model(|| {
            let mut slots = [0usize; 3];
            let base = SendPtr(slots.as_mut_ptr());
            let func: &(dyn Fn(usize) + Sync) = &move |t: usize| {
                // SAFETY: chunk `t` writes slot `t` only — disjoint slots,
                // each written by exactly one chunk.
                unsafe { *base.get().add(t) = t + 1 };
            };
            // SAFETY: same lifetime erasure as `run_delegated`: the caller
            // blocks on `wait()` (and joins) before `region`/`slots` die.
            let func: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(func) };
            let region = Region {
                func,
                remaining: AtomicUsize::new(2),
                panic_payload: Mutex::new(None),
                done: Mutex::new(false),
                cv: Condvar::new(),
            };
            let mut handles = Vec::new();
            for t in 1..3 {
                let chunk = Chunk { region: &region, t };
                handles.push(thread::spawn(move || {
                    // SAFETY: the caller blocks on `wait()` below before
                    // dropping `region` — the production `Chunk` contract.
                    let r = unsafe { &*chunk.region };
                    (r.func)(chunk.t);
                    r.finish_one();
                }));
            }
            (region.func)(0);
            region.wait();
            assert_eq!(slots, [1, 2, 3], "latch must publish all chunk writes");
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    /// A delegated chunk's panic payload, stored under the region mutex
    /// before `finish_one`, must be visible to the caller after `wait()` —
    /// the path that re-throws worker panics with their original message.
    #[test]
    fn region_panic_payload_crosses_the_latch() {
        loom::model(|| {
            fn noop(_t: usize) {}
            let func: &'static (dyn Fn(usize) + Sync) = &noop;
            let region = Region {
                func,
                remaining: AtomicUsize::new(1),
                panic_payload: Mutex::new(None),
                done: Mutex::new(false),
                cv: Condvar::new(),
            };
            let chunk = Chunk { region: &region, t: 1 };
            let h = thread::spawn(move || {
                // SAFETY: the caller blocks on `wait()` below before
                // dropping `region` — the production `Chunk` contract.
                let r = unsafe { &*chunk.region };
                // Mirror the worker's catch_unwind arm: store the payload,
                // then release the latch.
                let payload: Box<dyn std::any::Any + Send> = Box::new("model-boom");
                let mut slot = r.panic_payload.lock().unwrap_or_else(|p| p.into_inner());
                slot.get_or_insert(payload);
                drop(slot);
                r.finish_one();
            });
            region.wait();
            let taken = region.panic_payload.lock().unwrap_or_else(|p| p.into_inner()).take();
            let payload = taken.expect("panic payload must be visible after the latch");
            assert_eq!(payload.downcast_ref::<&str>(), Some(&"model-boom"));
            h.join().unwrap();
        });
    }

    /// Under loom, delegation is compile-time disabled (the OS pool is
    /// outside the model): `par_*` from any model thread must complete
    /// inline with the bit-identical sequential result — the same fallback
    /// the nested-region guard uses in production.
    #[test]
    fn par_calls_run_inline_under_loom() {
        loom::model(|| {
            let h = thread::spawn(|| {
                let mut out = [0u64; 8];
                par_chunks_mut(&mut out, 4, |off, chunk| {
                    for (i, o) in chunk.iter_mut().enumerate() {
                        *o = (off + i) as u64 + 1;
                    }
                });
                out
            });
            assert_eq!(h.join().unwrap(), [1, 2, 3, 4, 5, 6, 7, 8]);
        });
    }
}
