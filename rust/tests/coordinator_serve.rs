//! Coordinator integration: the batching service answers requests
//! correctly, batches them, accounts communication, and shuts down
//! cleanly. Requires artifacts + micronet weights (skips otherwise).

use hummingbird::coordinator::{Coordinator, LifecycleState, ServeOptions};
use hummingbird::error::Error;
use hummingbird::gmw::kernels::BinLayout;
use hummingbird::hummingbird::PlanSet;
use hummingbird::model::{Archive, Backend, Dataset, ModelConfig, PlainExecutor};
use hummingbird::net::fault::{FaultKind, FaultProfile};

const MODEL: &str = "micronet_synth10";

fn ready() -> Option<std::path::PathBuf> {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf();
    if repo.join("artifacts/manifest.json").exists()
        && repo.join(format!("artifacts/weights/{MODEL}.json")).exists()
    {
        Some(repo)
    } else {
        eprintln!("skipping: artifacts/weights missing");
        None
    }
}

#[test]
fn serve_batches_and_matches_plaintext() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();
    let weights = Archive::load(repo.join("artifacts/weights").join(MODEL)).unwrap();
    let plain = PlainExecutor::new(cfg.clone(), weights, Backend::Naive);

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::baseline(cfg.relu_groups));
    opts.batch_timeout = std::time::Duration::from_millis(10);
    let svc = Coordinator::start(opts).unwrap();

    // Submit an uneven number of requests (forces a padded tail batch).
    let n = 10usize;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push((i, svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap()));
    }
    let mut batch_sizes = Vec::new();
    for (i, rx) in rxs {
        let r = rx.recv().unwrap().unwrap();
        let want = plain.forward(dataset.test.batch(i, i + 1), 1).unwrap();
        let want_pred = PlainExecutor::argmax(&want, cfg.num_classes)[0];
        assert_eq!(r.pred, want_pred, "sample {i} prediction mismatch vs plaintext");
        assert_eq!(r.logits.len(), cfg.num_classes);
        assert!(r.latency_s > 0.0);
        batch_sizes.push(r.batch_size);
    }
    // Requests submitted together must have been batched (micronet batch=4).
    assert!(batch_sizes.iter().any(|b| *b > 1), "no batching occurred: {batch_sizes:?}");
    assert!(svc.metrics.samples_done() >= n as u64);
    assert!(svc.trace.total_bytes() > 0);
    let bd = svc.metrics.breakdown();
    assert!(bd.relu_s > 0.0 && bd.linear_s > 0.0);
    svc.shutdown();
}

/// The `--layout bitsliced` service produces the same predictions and the
/// same protocol bytes as the default lane layout (end-to-end through the
/// batcher, executor and GMW engine).
#[test]
fn serve_bitsliced_layout_matches_lane_layout() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let run = |layout: BinLayout| {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
        opts.layout = layout;
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap());
        }
        let preds: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().pred).collect();
        let by = svc.trace.bytes_by_phase();
        let protocol: u64 = by[..4].iter().sum();
        svc.shutdown();
        (preds, protocol)
    };
    let (lane_preds, lane_bytes) = run(BinLayout::LanePerU64);
    let (sliced_preds, sliced_bytes) = run(BinLayout::Bitsliced);
    assert_eq!(lane_preds, sliced_preds, "layout changed predictions");
    assert_eq!(lane_bytes, sliced_bytes, "layout changed protocol bytes");
}

/// `--prefetch on` serving (background offline-phase provisioning, warmed
/// before the party threads admit work) produces the same predictions and
/// the same protocol bytes as the synchronous dealer, end to end through
/// the batcher and executor.
#[test]
fn serve_prefetch_matches_sync_dealer() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let run = |prefetch: bool| {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
        opts.prefetch = prefetch;
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..6 {
            rxs.push(svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap());
        }
        let preds: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().pred).collect();
        let by = svc.trace.bytes_by_phase();
        let protocol: u64 = by[..4].iter().sum();
        svc.shutdown();
        (preds, protocol)
    };
    let (sync_preds, sync_bytes) = run(false);
    let (pf_preds, pf_bytes) = run(true);
    assert_eq!(sync_preds, pf_preds, "prefetch changed predictions");
    assert_eq!(sync_bytes, pf_bytes, "prefetch changed protocol bytes");
}

/// The XLA kernel backend is lane-per-u64 only; asking for the bitsliced
/// layout on it must fail fast at boot (config error, before any artifact
/// loading — so this runs without the artifacts directory).
#[test]
fn xla_backend_rejects_bitsliced_layout() {
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).to_path_buf();
    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.gmw_backend = "xla".into();
    opts.layout = BinLayout::Bitsliced;
    match Coordinator::start(opts) {
        Ok(_) => panic!("xla + bitsliced must be rejected at boot"),
        Err(e) => {
            let msg = e.to_string();
            assert!(msg.contains("layout"), "unexpected error: {msg}");
        }
    }
}

#[test]
fn serve_with_hummingbird_plan_reduces_bytes() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let run = |plan: PlanSet| {
        let mut opts = ServeOptions::new(&repo, MODEL);
        opts.plan = Some(plan);
        let svc = Coordinator::start(opts).unwrap();
        let mut rxs = Vec::new();
        for i in 0..4 {
            rxs.push(svc.infer_async(dataset.test.batch(i, i + 1).to_vec()).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let by = svc.trace.bytes_by_phase();
        let protocol: u64 = by[..4].iter().sum();
        svc.shutdown();
        protocol
    };
    let base = run(PlanSet::baseline(cfg.relu_groups));
    let hb = run(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
    assert!(
        base as f64 / hb as f64 > 2.5,
        "expected >2.5x byte cut through the service: {base} -> {hb}"
    );
}

/// Bounded admission (DESIGN.md §9): with `--queue-depth 1` and the
/// session stalled mid-batch (injected delay), the queue holds exactly
/// one waiting request — the next submission fast-fails with
/// `Error::Overloaded` (retryable by the client) instead of growing the
/// queue without bound.
#[test]
fn queue_depth_one_rejects_overload_with_stalled_session() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
    opts.queue_depth = 1;
    // Tiny fill window so request A is batched alone, then the injected
    // delay stalls its batch long enough to pile up B (queued) and C
    // (rejected).
    opts.batch_timeout = std::time::Duration::from_millis(1);
    opts.fault_profile = Some(FaultProfile::single(1, 0, FaultKind::Delay(1500)));
    let svc = Coordinator::start(opts).unwrap();

    let rx_a = svc.infer_async(dataset.test.batch(0, 1).to_vec()).unwrap();
    // Give the batcher time to dequeue A and block on the stalled batch.
    std::thread::sleep(std::time::Duration::from_millis(400));
    let rx_b = svc.infer_async(dataset.test.batch(1, 2).to_vec()).unwrap();
    let err = svc.infer_async(dataset.test.batch(2, 3).to_vec()).unwrap_err();
    assert!(matches!(err, Error::Overloaded(_)), "expected Overloaded, got {err}");
    assert!(err.client_should_retry(), "queue-full must invite a client retry");

    // The stall is a latency blip, not a fault: A and B still complete.
    rx_a.recv().unwrap().unwrap();
    rx_b.recv().unwrap().unwrap();
    let snap = svc.shutdown_with_deadline(std::time::Duration::from_secs(30));
    assert_eq!(snap.admission.shed_queue_full, 1);
    assert_eq!(snap.admission.admitted, 2);
    assert!(snap.balanced(), "identity must hold: {:?}", snap.admission);
    assert_eq!(snap.state, LifecycleState::Stopped);
    assert_eq!(snap.live_party_threads, 0);
}

/// Deadline shedding (DESIGN.md §9): a request whose
/// `--request-timeout-ms` deadline passed while it sat in the queue is
/// answered `Error::Deadline` at dequeue and never occupies a batch slot
/// (exactly one batch runs — the shed request spawns none).
#[test]
fn expired_queued_request_is_shed_without_a_batch_slot() {
    let Some(repo) = ready() else { return };
    let cfg = ModelConfig::load_named(&repo, MODEL).unwrap();
    let dataset = Dataset::load(repo.join("artifacts"), &cfg.dataset).unwrap();

    let mut opts = ServeOptions::new(&repo, MODEL);
    opts.plan = Some(PlanSet::uniform(cfg.relu_groups, 14, 6).unwrap());
    opts.batch_timeout = std::time::Duration::from_millis(1);
    // B's 50 ms deadline expires while A's batch is stalled for 1.5 s.
    opts.request_timeout = Some(std::time::Duration::from_millis(50));
    opts.fault_profile = Some(FaultProfile::single(1, 0, FaultKind::Delay(1500)));
    let svc = Coordinator::start(opts).unwrap();

    let rx_a = svc.infer_async(dataset.test.batch(0, 1).to_vec()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(400));
    let rx_b = svc.infer_async(dataset.test.batch(1, 2).to_vec()).unwrap();

    // A was dispatched before its deadline and completes despite the
    // blip; B expired in the queue and is shed at dequeue.
    rx_a.recv().unwrap().unwrap();
    let err = rx_b.recv().unwrap().unwrap_err();
    assert!(matches!(err, Error::Deadline(_)), "expected Deadline, got {err}");

    let snap = svc.shutdown_with_deadline(std::time::Duration::from_secs(30));
    assert_eq!(snap.admission.shed_deadline, 1);
    assert_eq!(snap.batches_done, 1, "the shed request must not spawn a batch");
    assert_eq!(snap.admission.completed, 1);
    assert!(snap.balanced(), "identity must hold: {:?}", snap.admission);
    assert_eq!(snap.state, LifecycleState::Stopped);
}
