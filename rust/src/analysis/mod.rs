//! `hblint`: the repo-invariant static analysis pass (DESIGN.md §8).
//!
//! HummingBird's headline claim is "faster **without introducing any
//! errors**", and the code that upholds it is the most dangerous in the
//! tree: lifetime-erasing `unsafe` in the worker pool, raw-pointer writers
//! in the bitsliced kernels, a background prefetch producer. Clippy cannot
//! express the repo-specific invariants those modules rely on, so this
//! module implements them as a dependency-free source-level lint — a
//! hand-rolled scanner in the spirit of `util/json.rs`, run as the blocking
//! `hblint` CI step and as part of `cargo test` (`tests/hblint.rs`).
//!
//! Five rules (see [`rules`] for the exact semantics):
//!
//! * **S** — every `unsafe` is immediately preceded by a `// SAFETY:`
//!   comment carrying the proof obligation.
//! * **A** — no allocating calls in the hot-path modules ([`HOT_PATHS`])
//!   outside `// HOT-PATH-ALLOW: <reason>` sites; the compile-time
//!   companion to the runtime arena/alloc-miss counters.
//! * **T** — every `Transport::exchange_all_into` impl records into
//!   `CommTrace` or delegates to an inner transport, so the exact
//!   byte/round accounting (README's headline tables) can never silently
//!   lose a transport.
//! * **U** — crate-wide `.unwrap()` / `.expect(` wall outside test modules,
//!   with `#[allow(clippy::unwrap_used)]` scopes honored and
//!   `// LINT-ALLOW: unwrap — <reason>` for individually reviewed sites.
//! * **M** — every `pub struct *Counters` group is surfaced as a field of
//!   `MetricsSnapshot` in the same file, so no counter block can silently
//!   drop out of the operator-visible snapshot (DESIGN.md §9).
//!
//! The linter lints itself (this module is part of `src/`), and self-tests
//! against a committed violation fixture: `tests/hblint_fixture/` holds a
//! file seeded with violations, each tagged `// EXPECT: <rule>`;
//! [`self_test`] checks the produced findings match the tags *exactly* —
//! both directions, so a rule that stops firing fails CI just like a rule
//! that misfires. The fixture directory is skipped by normal scans (cargo
//! does not compile it either: only top-level `tests/*.rs` are test
//! binaries).
//!
//! Run locally with `cargo run --bin hblint` (tree scan) and
//! `cargo run --bin hblint -- --self-test` (fixture check).

pub mod rules;
pub mod strip;

use std::fmt;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// Directories scanned relative to the crate root (`rust/`).
pub const SCAN_DIRS: [&str; 3] = ["src", "benches", "tests"];

/// Hot-path modules under rule `A` (path prefixes relative to the crate
/// root): the GMW engine, the bitpacked wire format, the transports and the
/// prefetch producer — everything on or feeding the online critical path.
pub const HOT_PATHS: [&str; 4] = ["src/gmw/", "src/bitpack/", "src/net/", "src/beaver/prefetch.rs"];

/// Allocating-call tokens banned by rule `A`. `.clone(` is included even
/// though some clones are cheap (e.g. `Range`) — the point is that every
/// clone in a hot module is an annotated, reviewed decision.
pub const ALLOC_TOKENS: [&str; 8] = [
    "Vec::new(",
    "vec![",
    ".to_vec(",
    ".collect(",
    ".to_owned(",
    "with_capacity(",
    "Box::new(",
    ".clone(",
];

/// The seeded-violation fixture directory, relative to the crate root.
/// Skipped by [`scan_tree`], scanned (with every rule forced on) by
/// [`self_test`].
pub const FIXTURE_DIR: &str = "tests/hblint_fixture";

/// Which rule produced a finding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `S`: `unsafe` without an immediately preceding `// SAFETY:`.
    Safety,
    /// `A`: un-annotated allocating call in a hot-path module.
    HotAlloc,
    /// `T`: `exchange_all_into` impl without CommTrace accounting.
    CommTrace,
    /// `U`: `.unwrap()` / `.expect(` outside the allowed scopes.
    UnwrapWall,
    /// `M`: `pub struct *Counters` not surfaced in `MetricsSnapshot`.
    MetricsSurface,
}

impl Rule {
    /// One-letter tag used in output and in fixture `EXPECT:` markers.
    pub fn tag(self) -> &'static str {
        match self {
            Rule::Safety => "S",
            Rule::HotAlloc => "A",
            Rule::CommTrace => "T",
            Rule::UnwrapWall => "U",
            Rule::MetricsSurface => "M",
        }
    }

    /// Inverse of [`Rule::tag`].
    pub fn from_tag(tag: &str) -> Option<Rule> {
        match tag {
            "S" => Some(Rule::Safety),
            "A" => Some(Rule::HotAlloc),
            "T" => Some(Rule::CommTrace),
            "U" => Some(Rule::UnwrapWall),
            "M" => Some(Rule::MetricsSurface),
            _ => None,
        }
    }
}

/// One lint violation, formatted `file:line: [tag] message`.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the crate root, with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.tag(), self.msg)
    }
}

/// Which rule sets apply to a file (derived from its path by [`classify`];
/// forced fully on for the self-test fixture).
#[derive(Debug, Clone, Copy)]
pub struct FileClass {
    /// Under rule `A` (hot-path module).
    pub hot: bool,
    /// Under rules `T`/`U` (library source, as opposed to benches/tests).
    pub walled: bool,
}

/// Derive a file's rule scope from its crate-relative path.
pub fn classify(rel: &str) -> FileClass {
    FileClass {
        hot: HOT_PATHS.iter().any(|p| rel.starts_with(p)),
        walled: rel.starts_with("src/"),
    }
}

/// Run every applicable rule over one file's source text.
pub fn check_file(rel: &str, text: &str, class: FileClass) -> Vec<Finding> {
    let s = strip::strip(text);
    let tmask = rules::test_mod_mask(&s.code);
    let mut out = rules::rule_safety(rel, &s);
    if class.hot {
        out.extend(rules::rule_hot_alloc(rel, &s, &tmask));
    }
    if class.walled {
        out.extend(rules::rule_comm_trace(rel, &s, &tmask));
        out.extend(rules::rule_unwrap_wall(rel, &s, &tmask));
        out.extend(rules::rule_metrics_surface(rel, &s, &tmask));
    }
    out.sort_by_key(|f| (f.line, f.rule));
    out
}

/// Scan the whole crate ([`SCAN_DIRS`], fixture excluded) and return every
/// finding, sorted by path. An empty result is the CI gate's green state.
pub fn scan_tree(root: &Path) -> Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for dir in SCAN_DIRS {
        let base = root.join(dir);
        if !base.is_dir() {
            return Err(Error::config(format!(
                "hblint scan dir missing: {} (run from the crate root?)",
                base.display()
            )));
        }
        let mut files = Vec::new();
        collect_rs_files(&base, &mut files)?;
        files.sort();
        for path in files {
            let rel = rel_path(root, &path);
            if rel.starts_with(FIXTURE_DIR) {
                continue;
            }
            let text = std::fs::read_to_string(&path)?;
            findings.extend(check_file(&rel, &text, classify(&rel)));
        }
    }
    Ok(findings)
}

/// Self-test against the committed violation fixture: every fixture file is
/// scanned with all rules forced on, and the findings must match the
/// file's `// EXPECT: <rule>` markers exactly (same lines, same rules).
/// Returns the number of seeded findings reproduced.
pub fn self_test(root: &Path) -> Result<usize> {
    let dir = root.join(FIXTURE_DIR);
    let mut files = Vec::new();
    collect_rs_files(&dir, &mut files)?;
    files.sort();
    if files.is_empty() {
        return Err(Error::config(format!("no fixture files under {}", dir.display())));
    }
    let mut total = 0;
    for path in files {
        let rel = rel_path(root, &path);
        let text = std::fs::read_to_string(&path)?;
        let expected = expected_findings(&text);
        if expected.is_empty() {
            return Err(Error::config(format!("{rel}: fixture has no EXPECT markers")));
        }
        let all = FileClass { hot: true, walled: true };
        let got: Vec<(usize, Rule)> =
            check_file(&rel, &text, all).into_iter().map(|f| (f.line, f.rule)).collect();
        for want in &expected {
            if !got.contains(want) {
                return Err(Error::config(format!(
                    "{rel}:{}: seeded [{}] violation was NOT detected — a rule went blind",
                    want.0,
                    want.1.tag()
                )));
            }
        }
        for have in &got {
            if !expected.contains(have) {
                return Err(Error::config(format!(
                    "{rel}:{}: unexpected [{}] finding — a rule misfires on clean code",
                    have.0,
                    have.1.tag()
                )));
            }
        }
        total += expected.len();
    }
    Ok(total)
}

/// Parse `// EXPECT: <tag>` markers out of a fixture file's comment view.
fn expected_findings(text: &str) -> Vec<(usize, Rule)> {
    let s = strip::strip(text);
    let mut out = Vec::new();
    for (i, cl) in s.comment.iter().enumerate() {
        let Some(pos) = cl.find("EXPECT:") else {
            continue;
        };
        for tok in cl[pos + "EXPECT:".len()..].split_whitespace() {
            if let Some(rule) = Rule::from_tag(tok) {
                out.push((i + 1, rule));
            }
        }
    }
    out
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_tags_roundtrip() {
        let all = [
            Rule::Safety,
            Rule::HotAlloc,
            Rule::CommTrace,
            Rule::UnwrapWall,
            Rule::MetricsSurface,
        ];
        for rule in all {
            assert_eq!(Rule::from_tag(rule.tag()), Some(rule));
        }
        assert_eq!(Rule::from_tag("X"), None);
    }

    #[test]
    fn classify_matches_declared_scopes() {
        assert!(classify("src/gmw/mod.rs").hot);
        assert!(classify("src/gmw/pipeline.rs").hot);
        assert!(classify("src/gmw/simd.rs").hot, "AVX2 kernels are hot-path (Rules A + S)");
        assert!(classify("src/net/sim.rs").hot, "WAN sim delay queue is hot-path (Rule A)");
        assert!(classify("src/beaver/prefetch.rs").hot);
        assert!(!classify("src/beaver/mod.rs").hot);
        assert!(!classify("src/model/plain.rs").hot);
        assert!(classify("src/model/plain.rs").walled);
        assert!(!classify("benches/bitpack.rs").walled);
        assert!(!classify("tests/doc_refs.rs").walled);
    }

    #[test]
    fn expect_markers_are_parsed_with_lines() {
        let text = "fn f() {\n    x(); // EXPECT: U\n    y(); // EXPECT: S A\n}\n";
        let exp = expected_findings(text);
        assert_eq!(exp, vec![(2, Rule::UnwrapWall), (3, Rule::Safety), (3, Rule::HotAlloc)]);
    }

    #[test]
    fn finding_display_is_clickable() {
        let f = Finding {
            file: "src/x.rs".to_string(),
            line: 7,
            rule: Rule::Safety,
            msg: "msg".to_string(),
        };
        assert_eq!(f.to_string(), "src/x.rs:7: [S] msg");
    }
}
