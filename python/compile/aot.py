"""AOT driver: lower every Layer-1/Layer-2 computation to HLO **text**.

HLO text (NOT `lowered.compile()` / proto `.serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the runtime's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under artifacts/):
    kernels/<name>_<bucket>.hlo.txt         — GMW elementwise Pallas kernels
    models/<config>/share_conv<i>.hlo.txt   — int64 ring conv (im2col+Pallas matmul)
    models/<config>/share_fc<i>.hlo.txt     — int64 ring fc
    models/<config>/plain_conv<i>.hlo.txt   — f32 conv+bias  (batch = MPC batch)
    models/<config>/search_conv<i>.hlo.txt  — f32 conv+bias  (batch = search batch)
    models/<config>/{plain,search}_fc<i>.hlo.txt
    manifest.json                           — shapes + paths for the Rust runtime

Run as `python -m compile.aot` (from python/); `make artifacts` wraps it.
"""

import argparse
import functools
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import archs, model as M
from .kernels import bitops

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", ".."))
ART = os.path.join(ROOT, "artifacts")

# Element-count buckets for the GMW elementwise kernels. The Rust runtime
# pads to the smallest fitting bucket and chunks above the largest.
KERNEL_BUCKETS = [1024, 8192, 32768]

I64 = jnp.int64
F32 = jnp.float32

SEARCH_BATCH = 64


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, specs, path, force=False):
    """Lower fn(*specs) and write HLO text; skip if the file exists."""
    if os.path.exists(path) and not force:
        return False
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    return True


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# GMW kernels.
# ---------------------------------------------------------------------------

def emit_kernels(force=False):
    entries = {}
    for n in KERNEL_BUCKETS:
        vec = spec((n,), I64)
        sc = spec((1,), I64)
        per_kernel = {
            "and_open": (bitops.and_open, [vec] * 4),
            "and_combine": (bitops.and_combine, [vec] * 5 + [sc]),
            "ks_stage_mid": (bitops.ks_stage_mid, [vec, vec, sc, sc]),
            "ks_stage_last": (bitops.ks_stage_last, [vec, vec, sc, sc]),
            "mult_open": (bitops.mult_open, [vec] * 4),
            "mult_combine": (bitops.mult_combine, [vec] * 5 + [sc]),
        }
        for name, (fn, specs) in per_kernel.items():
            rel = f"kernels/{name}_{n}.hlo.txt"
            wrote = lower_to_file(fn, specs, os.path.join(ART, rel), force)
            entries.setdefault(name, []).append({"n": n, "path": rel})
            if wrote:
                print(f"[aot] {rel}")
    return entries


# ---------------------------------------------------------------------------
# Per-model layers.
# ---------------------------------------------------------------------------

def emit_model(cfg, force=False):
    name = cfg["name"]
    batch = cfg["batch"]
    shapes = M.node_shapes(cfg)
    layers = {}
    for i, node in enumerate(cfg["nodes"]):
        op = node["op"]
        if op == "conv":
            cin, h, w = shapes[node["in"][0]]
            cout, ho, wo = shapes[i]
            k, stride, pad = node["k"], node["stride"], node["pad"]
            kdim = cin * k * k
            entry = {
                "op": "conv",
                "in_shape": [cin, h, w],
                "out_shape": [cout, ho, wo],
                "k": k, "stride": stride, "pad": pad,
                "wmat_shape": [kdim, cout],
                "w_shape": [cout, cin, k, k],
            }
            # Share-domain conv: Pallas ring-matmul variant ("share") and
            # the fused-dot fast variant ("share_fast", same ring math).
            for tag, fast in (("share", False), ("share_fast", True)):
                rel = f"models/{name}/{tag}_conv{i}.hlo.txt"
                fn = functools.partial(M.share_conv, k=k, stride=stride,
                                       pad=pad, out_ch=cout, fast=fast)
                if lower_to_file(fn, [spec((batch, cin, h, w), I64),
                                      spec((kdim, cout), I64)],
                                 os.path.join(ART, rel), force):
                    print(f"[aot] {rel}")
                entry[tag] = rel
            # Plain f32 conv at MPC batch and at search batch.
            for tag, b in (("plain", batch), ("search", SEARCH_BATCH)):
                rel = f"models/{name}/{tag}_conv{i}.hlo.txt"
                fn = functools.partial(M.conv_plain, stride=stride, pad=pad)
                if lower_to_file(fn, [spec((b, cin, h, w), F32),
                                      spec((cout, cin, k, k), F32),
                                      spec((cout,), F32)],
                                 os.path.join(ART, rel), force):
                    print(f"[aot] {rel}")
                entry[tag] = rel
            layers[str(i)] = entry
        elif op == "fc":
            in_shape = shapes[node["in"][0]]
            cin = 1
            for d in in_shape:
                cin *= d
            out = node["out"]
            entry = {"op": "fc", "in_dim": cin, "out_dim": out,
                     "wmat_shape": [cin, out]}
            for tag, fast in (("share", False), ("share_fast", True)):
                rel = f"models/{name}/{tag}_fc{i}.hlo.txt"
                fn = functools.partial(M.share_fc, fast=fast)
                if lower_to_file(fn, [spec((batch, cin), I64),
                                      spec((cin, out), I64)],
                                 os.path.join(ART, rel), force):
                    print(f"[aot] {rel}")
                entry[tag] = rel
            for tag, b in (("plain", batch), ("search", SEARCH_BATCH)):
                rel = f"models/{name}/{tag}_fc{i}.hlo.txt"
                if lower_to_file(M.fc_plain, [spec((b, cin), F32),
                                              spec((cin, out), F32),
                                              spec((out,), F32)],
                                 os.path.join(ART, rel), force):
                    print(f"[aot] {rel}")
                entry[tag] = rel
            layers[str(i)] = entry
    return {
        "batch": batch,
        "search_batch": SEARCH_BATCH,
        "frac_bits": cfg["frac_bits"],
        "layers": layers,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--models", nargs="*", default=None,
                    help="subset of config names (default: all)")
    ap.add_argument("--skip-models", action="store_true",
                    help="only emit the GMW kernels")
    args = ap.parse_args()

    os.makedirs(ART, exist_ok=True)
    archs.write_all_configs(os.path.join(ROOT, "configs", "models"))

    manifest = {"kernel_buckets": KERNEL_BUCKETS, "kernels": {}, "models": {}}
    manifest["kernels"] = emit_kernels(args.force)

    if not args.skip_models:
        wanted = args.models
        for m, ds in archs.BENCHMARKS + archs.EXTRA:
            cfg = archs.build_config(m, ds)
            if wanted and cfg["name"] not in wanted:
                continue
            print(f"[aot] model {cfg['name']}")
            manifest["models"][cfg["name"]] = emit_model(cfg, args.force)

    path = os.path.join(ART, "manifest.json")
    # Merge with an existing manifest so partial runs don't drop entries.
    if os.path.exists(path) and (args.models or args.skip_models):
        with open(path) as f:
            old = json.load(f)
        old["kernels"] = manifest["kernels"] or old.get("kernels", {})
        old.setdefault("models", {}).update(manifest["models"])
        old["kernel_buckets"] = manifest["kernel_buckets"]
        manifest = old
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {path}")


if __name__ == "__main__":
    main()
