//! Simulated WAN links: a [`Transport`] wrapper that delays frame delivery
//! per a [`NetworkProfile`]'s `latency + bytes / bandwidth` cost model
//! (DESIGN.md §10).
//!
//! Where [`super::profile`] *prices* a finished [`CommTrace`] analytically,
//! [`SimTransport`] *measures*: every exchange really waits out its modeled
//! wire time, so an end-to-end run over a simulated link reports the wall
//! clock a real WAN deployment would see — including the interaction with
//! compute and with the overlapped round schedule
//! ([`crate::gmw::pipeline`]), which the closed-form model cannot capture.
//!
//! # Clocking
//!
//! Delays run on an injected [`ClockHandle`] (the same abstraction the
//! crash-loop breaker uses, hence the `coordinator::breaker` import — it is
//! the crate's one clock seam). Two modes:
//!
//! - **Real time** ([`SimTransport::new`] / [`SimTransport::with_clock`]
//!   with a monotonic handle): waits are actual sleeps. Used by
//!   `benches/wan.rs` and `serve --net-profile` for wall-clock measurement.
//! - **Virtual time** ([`SimTransport::virtual_time`]): the wrapper owns a
//!   [`MockClock`] and *advances it itself* instead of sleeping, so tests
//!   assert exact modeled timestamps with zero wall delay. (A mock clock's
//!   `sleep` never advances time, so handing a mock handle to
//!   [`SimTransport::with_clock`] would spin forever — use this constructor
//!   instead.)
//!
//! # Link model
//!
//! One half-duplex-free uplink per party: a round's frame occupies the
//! sender's uplink for `bytes × 8 / bandwidth` seconds (serialization),
//! then lands one one-way `latency` later. Consecutive `exchange_begin`s
//! queue behind each other on the uplink but *share* the propagation
//! window — that is exactly the pipelining win the overlapped scheduler
//! exploits: two rounds in flight cost `tx₀ + tx₁ + latency`, not
//! `(tx₀ + latency) + (tx₁ + latency)` (DESIGN.md §10).
//!
//! Modeled wait per round is recorded into the inner transport's
//! [`CommTrace`] via `record_wait`, and aggregated in [`SimStats`].

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use super::accounting::{CommTrace, Phase};
use super::profile::NetworkProfile;
use super::{RecvBufs, Transport};
use crate::coordinator::breaker::{ClockHandle, MockClock};
use crate::error::Result;

/// Aggregate wire-time counters for one simulated endpoint.
#[derive(Debug, Default, Clone, Copy)]
pub struct SimStats {
    /// Rounds whose delivery this wrapper delayed.
    pub rounds: u64,
    /// Total modeled wire time actually waited (slept or mock-advanced).
    pub wire_wait: Duration,
}

/// A [`Transport`] wrapper that delays each round per a [`NetworkProfile`].
///
/// Composes with [`super::fault::FaultyTransport`] in either order; the
/// conventional stack is `FaultyTransport<SimTransport<T>>` so injected
/// faults hit a link that also has WAN timing.
#[derive(Debug)]
pub struct SimTransport<T: Transport> {
    inner: T,
    profile: NetworkProfile,
    clock: ClockHandle,
    /// `Some` in virtual-time mode: waits advance this mock instead of
    /// sleeping on `clock`.
    mock: Option<Arc<MockClock>>,
    /// When this party's uplink finishes serializing its last queued frame.
    link_free_at: Duration,
    /// Modeled delivery deadline for each in-flight (begun, unfinished)
    /// round, FIFO. Copy metadata only — no per-frame allocation (Rule A).
    inflight: VecDeque<Duration>,
    stats: SimStats,
}

impl<T: Transport> SimTransport<T> {
    /// Wrap `inner` with real-time delays on the monotonic clock.
    pub fn new(inner: T, profile: NetworkProfile) -> Self {
        SimTransport::with_clock(inner, profile, ClockHandle::monotonic())
    }

    /// Wrap `inner` with real-time delays on an injected clock. The handle
    /// must be one whose `sleep` really waits (see module doc); for mock
    /// clocks use [`SimTransport::virtual_time`].
    pub fn with_clock(inner: T, profile: NetworkProfile, clock: ClockHandle) -> Self {
        SimTransport {
            inner,
            profile,
            clock,
            mock: None,
            link_free_at: Duration::ZERO,
            inflight: VecDeque::new(),
            stats: SimStats::default(),
        }
    }

    /// Wrap `inner` in virtual-time mode: delays advance the returned
    /// [`MockClock`] instead of sleeping, so a "50 ms RTT" run finishes in
    /// microseconds of wall time while the clock reads the modeled total.
    pub fn virtual_time(inner: T, profile: NetworkProfile) -> (Self, Arc<MockClock>) {
        let (clock, mock) = ClockHandle::mock();
        let mut sim = SimTransport::with_clock(inner, profile, clock);
        sim.mock = Some(Arc::clone(&mock));
        (sim, mock)
    }

    /// Wire-time counters accumulated so far.
    pub fn stats(&self) -> SimStats {
        self.stats
    }

    /// The link profile this wrapper simulates.
    pub fn profile(&self) -> &NetworkProfile {
        &self.profile
    }

    pub fn inner(&self) -> &T {
        &self.inner
    }

    pub fn into_inner(self) -> T {
        self.inner
    }

    /// Price one begun round: occupy the uplink for the serialization time
    /// of `bytes`, and return the modeled delivery instant (uplink free +
    /// one one-way latency). Pure queue math — nothing waits here.
    fn price_begin(&mut self, bytes: usize) -> Duration {
        let now = self.clock.now();
        let tx = Duration::from_secs_f64(bytes as f64 * 8.0 / self.profile.bandwidth_bps);
        let start = if self.link_free_at > now { self.link_free_at } else { now };
        self.link_free_at = start + tx;
        self.link_free_at + Duration::from_secs_f64(self.profile.latency_s)
    }

    /// Wait (really or virtually) until the modeled instant `deliver`, and
    /// account the wait as wire time.
    fn wait_until(&mut self, deliver: Duration) {
        let remaining = deliver.saturating_sub(self.clock.now());
        if !remaining.is_zero() {
            match &self.mock {
                Some(mock) => mock.advance(remaining),
                None => self.clock.sleep(remaining),
            }
        }
        self.stats.rounds += 1;
        self.stats.wire_wait += remaining;
        self.inner.trace().record_wait(remaining);
    }
}

impl<T: Transport> Transport for SimTransport<T> {
    fn party(&self) -> usize {
        self.inner.party()
    }

    fn parties(&self) -> usize {
        self.inner.parties()
    }

    fn exchange_all_into(&mut self, phase: Phase, data: &[u8], recv: &mut RecvBufs) -> Result<()> {
        // Serial round: price after the inner exchange succeeds, then wait
        // out the full modeled delivery. Delegation keeps byte accounting
        // in the inner transport's `.exchange_all_into`.
        self.inner.exchange_all_into(phase, data, recv)?;
        let deliver = self.price_begin(data.len() * (self.inner.parties() - 1));
        self.wait_until(deliver);
        Ok(())
    }

    fn exchange_begin(&mut self, phase: Phase, data: &[u8]) -> Result<()> {
        self.inner.exchange_begin(phase, data)?;
        let deliver = self.price_begin(data.len() * (self.inner.parties() - 1));
        self.inflight.push_back(deliver);
        Ok(())
    }

    fn exchange_finish(&mut self, phase: Phase, data: &[u8], recv: &mut RecvBufs) -> Result<()> {
        self.inner.exchange_finish(phase, data, recv)?;
        if let Some(deliver) = self.inflight.pop_front() {
            self.wait_until(deliver);
        }
        Ok(())
    }

    fn trace(&self) -> Arc<CommTrace> {
        self.inner.trace()
    }

    fn inject_peer_drop(&mut self, peer: usize) -> bool {
        self.inner.inject_peer_drop(peer)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::super::fault::{FaultKind, FaultProfile, FaultyTransport};
    use super::super::local::hub;
    use super::*;

    /// 8 Mbit/s ⇒ 1 µs per byte; 10 ms one-way latency. With 2 parties a
    /// 1000-byte payload prices as tx = 1 ms per round.
    fn pin_profile() -> NetworkProfile {
        NetworkProfile::new("pin", 10e-3, 8e6)
    }

    fn approx(d: Duration, secs: f64) {
        assert!((d.as_secs_f64() - secs).abs() < 1e-6, "{d:?} !~ {secs}s");
    }

    /// A peer thread that serves `rounds` plain exchanges on the raw hub
    /// endpoint (the peer does not need to be simulated for party 0's
    /// timing to be modeled).
    fn spawn_peer(
        mut t: impl Transport + 'static,
        rounds: usize,
        payload: usize,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut recv = RecvBufs::new(t.parties());
            for r in 0..rounds {
                let data = vec![r as u8; payload];
                t.exchange_all_into(Phase::Circuit, &data, &mut recv).unwrap();
            }
        })
    }

    #[test]
    fn serial_rounds_each_pay_latency() {
        let mut hub = hub(2);
        let peer = hub.pop().unwrap();
        let (mut sim, mock) = SimTransport::virtual_time(hub.pop().unwrap(), pin_profile());
        let h = spawn_peer(peer, 2, 1000);

        let mut recv = RecvBufs::new(2);
        let data = vec![0u8; 1000];
        sim.exchange_all_into(Phase::Circuit, &data, &mut recv).unwrap();
        approx(mock.now(), 0.011); // tx + L
        let data = vec![1u8; 1000];
        sim.exchange_all_into(Phase::Circuit, &data, &mut recv).unwrap();
        approx(mock.now(), 0.022); // 2 × (tx + L)
        h.join().unwrap();

        let stats = sim.stats();
        assert_eq!(stats.rounds, 2);
        approx(stats.wire_wait, 0.022);
        // Modeled waits land in the inner trace for §10 accounting.
        assert!(sim.trace().wait_seconds() > 0.021);
    }

    #[test]
    fn pipelined_rounds_share_the_latency_window() {
        let mut hub = hub(2);
        let peer = hub.pop().unwrap();
        let (mut sim, mock) = SimTransport::virtual_time(hub.pop().unwrap(), pin_profile());
        let h = spawn_peer(peer, 2, 1000);

        let r0 = vec![7u8; 1000];
        let r1 = vec![9u8; 1000];
        sim.exchange_begin(Phase::Circuit, &r0).unwrap();
        sim.exchange_begin(Phase::Circuit, &r1).unwrap();
        approx(mock.now(), 0.0); // begins never wait

        let mut recv = RecvBufs::new(2);
        sim.exchange_finish(Phase::Circuit, &r0, &mut recv).unwrap();
        assert_eq!(recv.get(1), &[0u8; 1000][..]); // peer round 0 payload
        approx(mock.now(), 0.011); // tx₀ + L
        sim.exchange_finish(Phase::Circuit, &r1, &mut recv).unwrap();
        assert_eq!(recv.get(1), &[1u8; 1000][..]); // no reordering per peer
        approx(mock.now(), 0.012); // tx₀ + tx₁ + L, not 2 × (tx + L)
        h.join().unwrap();
        assert_eq!(sim.stats().rounds, 2);
    }

    #[test]
    fn composes_under_faulty_transport() {
        let mut hub = hub(2);
        let peer = hub.pop().unwrap();
        let (sim, mock) = SimTransport::virtual_time(hub.pop().unwrap(), pin_profile());
        // Fault at round 1: round 0 sails through with modeled delay,
        // round 1 dies before the inner (simulated) link is touched.
        let profile = FaultProfile::single(0, 1, FaultKind::Drop);
        let mut t = FaultyTransport::new(sim, &profile);
        let h = spawn_peer(peer, 1, 16);

        let mut recv = RecvBufs::new(2);
        let data = vec![3u8; 16];
        t.exchange_all_into(Phase::Circuit, &data, &mut recv).unwrap();
        let after_round0 = mock.now();
        approx(after_round0, 10e-3 + 16.0 * 8.0 / 8e6);

        let err = t.exchange_all_into(Phase::Circuit, &data, &mut recv);
        assert!(err.is_err(), "dropped round must fail");
        assert_eq!(mock.now(), after_round0, "failed round pays no modeled wire time");
        h.join().unwrap();
    }

    #[test]
    fn slow_clock_means_no_extra_wait() {
        // If compute already burned past the delivery instant, the wire
        // wait is zero — this is what makes e2e ≈ max(compute, wire).
        let mut hub = hub(2);
        let peer = hub.pop().unwrap();
        let (mut sim, mock) = SimTransport::virtual_time(hub.pop().unwrap(), pin_profile());
        let h = spawn_peer(peer, 1, 1000);

        let data = vec![0u8; 1000];
        sim.exchange_begin(Phase::Circuit, &data).unwrap();
        mock.advance(Duration::from_millis(40)); // "compute" dominates
        let mut recv = RecvBufs::new(2);
        sim.exchange_finish(Phase::Circuit, &data, &mut recv).unwrap();
        approx(mock.now(), 0.040);
        assert_eq!(sim.stats().wire_wait, Duration::ZERO);
        h.join().unwrap();
    }
}
