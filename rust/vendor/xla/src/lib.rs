//! Offline stub of the `xla` PJRT bindings.
//!
//! The HummingBird runtime layer (`hummingbird::runtime`) is written against
//! the xla-rs API (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`). This container has no XLA/PJRT install, so this
//! stub provides the exact API surface the crate uses: everything compiles,
//! and every entry point that would touch the real runtime returns
//! [`Error::Unavailable`] at run time. Tests and benches that need compiled
//! HLO artifacts check for `artifacts/manifest.json` first and skip cleanly,
//! so they never reach these error paths.
//!
//! Swapping in the real bindings is a one-line `Cargo.toml` change; no
//! source edits are required.

use std::fmt;
use std::path::Path;

/// Stub error: the PJRT runtime is not available in this build.
#[derive(Debug)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: PJRT runtime not available (offline xla stub)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types a [`Literal`] can carry (subset the crate uses).
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Stub of the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of an HLO module parsed from text.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of an XLA computation built from an HLO proto.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on device; returns per-device, per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of a device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of a host literal.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }
}

/// Stub of an array shape descriptor.
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("not available"));
        let err = Literal.to_vec::<i64>().err().unwrap();
        assert!(err.to_string().contains("Literal::to_vec"));
    }
}
